//! Driver-agnostic policy/event core.
//!
//! [`GoghCore`] owns everything the GOGH control loop needs that is not
//! the policy itself: the simulated [`Cluster`] substrate, the
//! [`Monitor`], the energy meters, the time-ordered event queue, and the
//! per-run accounting that becomes a [`RunReport`]. Two frontends
//! consume it:
//!
//! * the **simulator** ([`crate::coordinator::SimDriver`]): loads a
//!   trace, then calls [`GoghCore::run`] — the virtual clock jumps from
//!   event to event and the run report is byte-stable;
//! * the **daemon** (`goghd`, [`crate::daemon`]): injects events as
//!   network requests arrive and calls [`GoghCore::advance_to`] with a
//!   wall-clock-derived time — the same queue, dispatch and integration
//!   code paths, driven in real time.
//!
//! Keeping one event loop for both is what makes the daemon's behaviour
//! exactly the simulator's (and keeps the e2e comparison table honest):
//! there is no second scheduler loop to drift out of sync.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};

use crate::cluster::energy::EnergyMeter;
use crate::cluster::{AccelId, Cluster, ClusterSpec, Monitor};
use crate::coordinator::{ClusterEvent, Scheduler};
use crate::metrics::{LatencyHistogram, RunReport};
use crate::power::{state_power_watts, CarbonSignal};
use crate::workload::{serving, AccelType, JobId, JobSpec, ThroughputOracle, Trace, TraceEvent};
use crate::Result;

/// Substrate knobs shared by both frontends: [`GoghCore`] here and the
/// simulator's [`crate::coordinator::SimDriver`] consume the same
/// struct (via `with_options`), so a new knob is added in exactly one
/// place instead of duplicating builder setters on each type.
#[derive(Debug, Clone, Default)]
pub struct EngineOptions {
    /// restart penalty charged to every migrated or resumed job
    /// (seconds of stall; 0 = free migrations).
    pub migration_cost_s: f64,
    /// cluster power cap in worst-case watts (None = uncapped).
    pub power_cap_w: Option<f64>,
    /// diurnal carbon/price signal for emissions accounting.
    pub carbon: Option<CarbonSignal>,
}

impl EngineOptions {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge every migrated/resumed job `cost_s` seconds of restart
    /// stall (integrated into energy, SLO and JCT accounting).
    pub fn with_migration_cost(mut self, cost_s: f64) -> Self {
        self.migration_cost_s = cost_s.max(0.0);
        self
    }

    /// Cap the cluster's worst-case draw at `cap_w` watts: policy
    /// deltas are trimmed to fit (see [`Cluster::trim_to_power_cap`])
    /// and the cluster rejects anything that still breaches.
    pub fn with_power_cap(mut self, cap_w: Option<f64>) -> Self {
        self.power_cap_w = cap_w;
        self
    }

    /// Attach a diurnal carbon/price signal: the meters accrue gCO₂
    /// and the `power:` report carries it (schedulers read the same
    /// signal from their own options to reweight the objective).
    pub fn with_carbon(mut self, signal: Option<CarbonSignal>) -> Self {
        self.carbon = signal;
        self
    }
}

/// One queued input to the core: trace events, network submissions and
/// the self-rescheduling monitor tick share this queue.
#[derive(Debug, Clone, PartialEq)]
pub enum CoreEvent {
    /// A job enters the system at the queued time.
    Arrival(JobSpec),
    /// The owner cancels a job (ignored if it already finished).
    Cancel(JobId),
    /// Periodic monitoring round; reschedules itself.
    MonitorTick,
    /// An accelerator instance goes out of service.
    AccelDown(AccelId),
    /// An accelerator instance returns to service.
    AccelUp(AccelId),
}

struct QueueEntry {
    at: f64,
    seq: u64,
    ev: CoreEvent,
}

impl PartialEq for QueueEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for QueueEntry {}
impl PartialOrd for QueueEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueueEntry {
    /// `BinaryHeap` is a max-heap: earliest time pops first, ties break
    /// by insertion order (lower seq first) for determinism.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .at
            .total_cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Time-ordered event queue with deterministic FIFO tie-breaking.
#[derive(Default)]
struct EventQueue {
    heap: BinaryHeap<QueueEntry>,
    seq: u64,
}

impl EventQueue {
    fn push(&mut self, at: f64, ev: CoreEvent) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(QueueEntry { at, seq, ev });
    }

    fn pop(&mut self) -> Option<QueueEntry> {
        self.heap.pop()
    }

    fn peek_at(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.at)
    }
}

/// Per-run bookkeeping (JCT, queueing delay, decision latency).
#[derive(Default)]
struct Accounting {
    jct_sum: f64,
    arrival_time: HashMap<JobId, f64>,
    first_place: HashMap<JobId, f64>,
    queue_wait_sum: f64,
    queue_waits: usize,
    decision_s: f64,
    /// per-event decision-latency distribution, recorded in
    /// *milliseconds* (re-using the histogram's 1e-3..1e3 domain as
    /// 1 µs..1000 ms so the sub-millisecond decision path resolves)
    decision_hist: LatencyHistogram,
    /// jobs evicted by an AccelDown; they pay the restart penalty when
    /// re-placed (the eviction happens outside `apply_delta`, so
    /// `DeltaOutcome::migrated_jobs` cannot see them).
    failure_evicted: std::collections::BTreeSet<JobId>,
    /// time-weighted serving-latency distribution over all inference jobs
    inf_hist: LatencyHistogram,
    /// seconds of inference serving-time inside the latency SLO
    inf_attained_s: f64,
    /// total seconds of inference serving-time observed
    inf_total_s: f64,
    /// per-job (attained, total) serving seconds, for the SLO-met count
    inf_job_time: HashMap<JobId, (f64, f64)>,
    /// peak instantaneous measured cluster power (W)
    peak_power_w: f64,
    /// integration intervals measured, and of those, within the cap
    cap_intervals: usize,
    cap_ok_intervals: usize,
    /// seconds jobs spent parked by Suspend ops (summed over jobs)
    suspended_s: f64,
    /// ideal exclusive JCT per training job: work ÷ best solo
    /// ground-truth throughput at submit — the finish-time-fairness
    /// denominator (Gavel, PAPERS.md)
    ideal_jct: HashMap<JobId, f64>,
    /// finish-time fairness (actual ÷ ideal JCT) of completed training
    /// jobs, pushed at completion, quantiled at report time
    ftf: Vec<f64>,
    /// per-priority-tier (attained, total) SLO-scored seconds; parked
    /// jobs count toward the total but never toward attained
    tier_time: [(f64, f64); 3],
}

/// The shared policy/event core: cluster + monitor + meters + event
/// queue + run accounting, independent of what drives the clock.
///
/// Events enter via [`submit`](GoghCore::submit),
/// [`cancel`](GoghCore::cancel), [`set_accel`](GoghCore::set_accel) or
/// [`load_trace`](GoghCore::load_trace); they are dispatched to the
/// policy by [`step`](GoghCore::step) (one event),
/// [`run`](GoghCore::run) (the simulator loop) or
/// [`advance_to`](GoghCore::advance_to) (the daemon's wall clock).
pub struct GoghCore {
    cluster: Cluster,
    monitor: Monitor,
    meter_busy: EnergyMeter,
    meter_total: EnergyMeter,
    queue: EventQueue,
    state: Accounting,
    /// raw counters accrued so far; derived fields are filled by
    /// [`GoghCore::report`].
    report: RunReport,
    monitor_interval_s: f64,
    /// restart penalty charged to every migrated job (seconds of stall).
    migration_cost_s: f64,
    /// carbon/price signal for emissions accounting (docs/POWER.md).
    carbon: Option<CarbonSignal>,
    /// Distinct trace cycles can collide on one physical instance
    /// (accel_index is taken modulo the cluster size), so outages are
    /// reference-counted: an instance is down while any cycle holds it.
    down_votes: HashMap<AccelId, u32>,
    arrivals_pending: usize,
    last_arrival_t: f64,
    monitor_started: bool,
}

impl GoghCore {
    /// Build a core. Fails if `monitor_interval_s` is not strictly
    /// positive — a zero interval would spin the event loop forever at
    /// t = 0 (this is the single validation point; callers must not
    /// patch the interval themselves).
    pub fn new(
        spec: ClusterSpec,
        oracle: ThroughputOracle,
        noise_sigma: f64,
        monitor_interval_s: f64,
        seed: u64,
    ) -> Result<Self> {
        anyhow::ensure!(
            monitor_interval_s > 0.0 && monitor_interval_s.is_finite(),
            "monitor_interval_s must be > 0 (got {monitor_interval_s})"
        );
        Ok(Self {
            cluster: Cluster::new(spec),
            monitor: Monitor::new(oracle, noise_sigma, seed),
            meter_busy: EnergyMeter::new(),
            meter_total: EnergyMeter::new(),
            queue: EventQueue::default(),
            state: Accounting::default(),
            report: RunReport::default(),
            monitor_interval_s,
            migration_cost_s: 0.0,
            carbon: None,
            down_votes: HashMap::new(),
            arrivals_pending: 0,
            last_arrival_t: 0.0,
            monitor_started: false,
        })
    }

    /// Apply the shared substrate knobs (the one configuration point —
    /// [`SimDriver`](crate::coordinator::SimDriver) forwards its own
    /// `with_options` here).
    pub fn with_options(mut self, opts: EngineOptions) -> Self {
        self.migration_cost_s = opts.migration_cost_s.max(0.0);
        self.cluster.set_power_cap(opts.power_cap_w);
        self.carbon = opts.carbon;
        self
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Mutable cluster access — restore/rebuild hooks only; frontends
    /// must not mutate placement state behind the policy's back.
    pub fn cluster_mut(&mut self) -> &mut Cluster {
        &mut self.cluster
    }

    pub fn monitor(&self) -> &Monitor {
        &self.monitor
    }

    pub fn monitor_interval_s(&self) -> f64 {
        self.monitor_interval_s
    }

    /// Queued arrivals not yet dispatched.
    pub fn pending_arrivals(&self) -> usize {
        self.arrivals_pending
    }

    /// Latest arrival time enqueued so far (drain-timeout anchor).
    pub fn last_arrival_t(&self) -> f64 {
        self.last_arrival_t
    }

    /// All arrivals dispatched and no jobs left in the system.
    pub fn drained(&self) -> bool {
        self.arrivals_pending == 0 && self.cluster.n_jobs() == 0
    }

    /// Time of the next queued event, if any.
    pub fn next_event_at(&self) -> Option<f64> {
        self.queue.peek_at()
    }

    /// When the given job arrived (None if the core never saw it).
    pub fn arrival_time(&self, j: JobId) -> Option<f64> {
        self.state.arrival_time.get(&j).copied()
    }

    // -- event intake ----------------------------------------------------

    /// Enqueue a job arrival at time `at`.
    pub fn submit(&mut self, at: f64, job: JobSpec) {
        self.report.jobs_total += 1;
        if job.is_inference() {
            self.report.inference_total += 1;
        }
        self.note_ideal_jct(&job);
        self.arrivals_pending += 1;
        self.last_arrival_t = self.last_arrival_t.max(at);
        self.queue.push(at, CoreEvent::Arrival(job));
    }

    /// Enqueue a cancellation at time `at` (ignored at dispatch if the
    /// job already completed).
    pub fn cancel(&mut self, at: f64, job: JobId) {
        self.queue.push(at, CoreEvent::Cancel(job));
    }

    /// Enqueue accelerator churn at time `at`.
    pub fn set_accel(&mut self, at: f64, accel: AccelId, up: bool) {
        let ev = if up {
            CoreEvent::AccelUp(accel)
        } else {
            CoreEvent::AccelDown(accel)
        };
        self.queue.push(at, ev);
    }

    /// Load a full trace into the queue (arrivals, cancellations and
    /// churn, in trace order — the FIFO tie-break preserves it).
    pub fn load_trace(&mut self, trace: &Trace) {
        let n_accels = self.cluster.spec.len();
        for ev in &trace.events {
            match ev {
                TraceEvent::Arrival { at, job } => self.submit(*at, job.clone()),
                TraceEvent::Cancel { at, job } => self.cancel(*at, *job),
                TraceEvent::AccelChurn { at, accel_index, up } if n_accels > 0 => {
                    let aid = self.cluster.spec.accels[accel_index % n_accels];
                    self.set_accel(*at, aid, *up);
                }
                TraceEvent::AccelChurn { .. } => {} // no accelerators to churn
            }
        }
    }

    /// Schedule the first monitor tick (idempotent; ticks reschedule
    /// themselves afterwards). Frontends call this once after intake is
    /// primed so the tick's queue position stays deterministic.
    pub fn start_monitor(&mut self) {
        if !self.monitor_started {
            self.monitor_started = true;
            let at = self.cluster.now() + self.monitor_interval_s;
            self.queue.push(at, CoreEvent::MonitorTick);
        }
    }

    /// Restore hook: re-register a job that was live in a snapshot,
    /// keeping its original arrival time for JCT accounting.
    pub fn restore_job(&mut self, job: JobSpec, arrived_at: f64) {
        self.state.arrival_time.insert(job.id, arrived_at);
        self.note_ideal_jct(&job);
        self.cluster.add_job(job);
    }

    /// Record the job's ideal exclusive JCT — its work at the best solo
    /// ground-truth throughput anywhere in the cluster, as if it ran
    /// alone from arrival. The finish-time-fairness ratio reported as
    /// `ftf_p99` divides the actual JCT by this (Gavel, PAPERS.md);
    /// inference jobs serve until cancelled and are scored by latency
    /// attainment instead.
    fn note_ideal_jct(&mut self, job: &JobSpec) {
        if job.is_inference() {
            return;
        }
        let oracle = self.monitor.oracle();
        let best = crate::workload::ACCEL_TYPES
            .iter()
            .map(|a| oracle.solo(job, *a))
            .fold(0.0_f64, f64::max);
        if best > 0.0 && job.work > 0.0 {
            self.state.ideal_jct.insert(job.id, job.work / best);
        }
    }

    /// Restore hook: seed the run counters a snapshot carried across a
    /// daemon restart (totals only; time-integrated metrics restart).
    pub fn restore_counters(&mut self, total: usize, completed: usize, cancelled: usize) {
        self.report.jobs_total = total;
        self.report.jobs_completed = completed;
        self.report.jobs_cancelled = cancelled;
    }

    /// Restore hook: re-enqueue a pending event captured in a snapshot.
    /// Unlike [`Self::submit`], arrivals do not bump `jobs_total` —
    /// the counters restored from the snapshot already include them.
    pub fn restore_event(&mut self, at: f64, ev: CoreEvent) {
        if matches!(ev, CoreEvent::Arrival(_)) {
            self.arrivals_pending += 1;
            self.last_arrival_t = self.last_arrival_t.max(at);
        }
        self.queue.push(at, ev);
    }

    /// Pending queue contents in dispatch order, excluding the
    /// self-rescheduling monitor tick (snapshot capture).
    pub fn pending_events(&self) -> Vec<(f64, CoreEvent)> {
        let mut v: Vec<(f64, u64, CoreEvent)> = self
            .queue
            .heap
            .iter()
            .filter(|e| !matches!(e.ev, CoreEvent::MonitorTick))
            .map(|e| (e.at, e.seq, e.ev.clone()))
            .collect();
        v.sort_by(|a, b| a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
        v.into_iter().map(|(at, _, ev)| (at, ev)).collect()
    }

    // -- event loop ------------------------------------------------------

    /// Pop and process exactly one queued event: integrate the substrate
    /// up to the event time, then dispatch it to the policy. Returns
    /// `false` when the queue is empty.
    pub fn step(&mut self, policy: &mut dyn Scheduler) -> Result<bool> {
        let Some(entry) = self.queue.pop() else {
            return Ok(false);
        };
        let now = self.cluster.now();
        let t = entry.at.max(now);
        // ---- integrate [now, t] (detects + dispatches completions)
        self.integrate(now, t, policy)?;
        self.cluster.advance_to(t);

        // ---- dispatch the event
        match entry.ev {
            CoreEvent::Arrival(job) => {
                self.arrivals_pending -= 1;
                let id = job.id;
                self.state.arrival_time.insert(id, t);
                self.cluster.add_job(job);
                let ev = ClusterEvent::JobArrived { job: id };
                self.dispatch(policy, ev)?;
            }
            CoreEvent::Cancel(j) => {
                // ignore cancellations racing a completed/unknown job
                if self.cluster.job(j).is_some() {
                    self.cluster.remove_job(j);
                    self.report.jobs_cancelled += 1;
                    let ev = ClusterEvent::JobCancelled { job: j };
                    self.dispatch(policy, ev)?;
                }
            }
            CoreEvent::MonitorTick => {
                let measurements = self.monitor.sample(&self.cluster);
                let ev = ClusterEvent::MonitorTick { measurements };
                self.dispatch(policy, ev)?;
                self.queue.push(t + self.monitor_interval_s, CoreEvent::MonitorTick);
            }
            CoreEvent::AccelDown(a) => {
                let votes = self.down_votes.entry(a).or_insert(0);
                *votes += 1;
                if *votes == 1 {
                    let evicted = self.cluster.set_accel_down(a);
                    self.state.failure_evicted.extend(evicted);
                    let ev = ClusterEvent::AccelDown { accel: a };
                    self.dispatch(policy, ev)?;
                }
            }
            CoreEvent::AccelUp(a) => {
                let votes = self.down_votes.entry(a).or_insert(0);
                if *votes > 0 {
                    *votes -= 1;
                    if *votes == 0 {
                        self.cluster.set_accel_up(a);
                        let ev = ClusterEvent::AccelUp { accel: a };
                        self.dispatch(policy, ev)?;
                    }
                }
            }
        }
        Ok(true)
    }

    /// The simulator loop: process queued events until the system drains
    /// (every arrival dispatched, no jobs left) or the drain timeout
    /// after the last arrival trips. The monitor tick keeps the queue
    /// non-empty, so termination is exactly these two conditions.
    pub fn run(&mut self, policy: &mut dyn Scheduler, drain_limit_s: f64) -> Result<()> {
        self.start_monitor();
        while self.step(policy)? {
            let timed_out = self.cluster.now() > self.last_arrival_t + drain_limit_s;
            if self.drained() || timed_out {
                break;
            }
        }
        Ok(())
    }

    /// The daemon loop body: process every event due at or before `t`
    /// (wall-clock-derived simulated time), then integrate the tail so
    /// job progress and energy track real time even between events.
    pub fn advance_to(&mut self, t: f64, policy: &mut dyn Scheduler) -> Result<()> {
        while self.next_event_at().map_or(false, |at| at <= t) {
            self.step(policy)?;
        }
        let now = self.cluster.now();
        if t > now {
            self.integrate(now, t, policy)?;
            self.cluster.advance_to(t);
        }
        Ok(())
    }

    /// Snapshot the run metrics accumulated so far into a finalized
    /// [`RunReport`] (derived means/quantiles filled in). Non-consuming:
    /// the daemon calls this on every `status` request.
    pub fn report(&self, policy: &dyn Scheduler) -> RunReport {
        let mut report = self.report.clone();
        report.scheduler = policy.name().to_string();
        report.sim_seconds = self.cluster.now();
        report.energy_joules = self.meter_busy.total_joules();
        report.total_energy_joules = self.meter_total.total_joules();
        report.mean_jct = if report.jobs_completed > 0 {
            self.state.jct_sum / report.jobs_completed as f64
        } else {
            f64::NAN
        };
        report.mean_queue_s = if self.state.queue_waits > 0 {
            self.state.queue_wait_sum / self.state.queue_waits as f64
        } else {
            0.0
        };
        report.mean_decision_ms = if report.events > 0 {
            1000.0 * self.state.decision_s / report.events as f64
        } else {
            0.0
        };
        // histogram units are ms (see Accounting::decision_hist), so
        // the quantile reads back as milliseconds directly
        report.p99_decision_ms = if self.state.decision_hist.total_weight() > 0.0 {
            self.state.decision_hist.quantile(0.99)
        } else {
            0.0
        };
        report.estimation_mae = policy.estimation_mae();
        let (solve_ms, p1_ms) = policy.decision_latencies();
        report.mean_solve_ms = solve_ms;
        report.mean_p1_ms = p1_ms;
        report.inference_attainment = if self.state.inf_total_s > 0.0 {
            self.state.inf_attained_s / self.state.inf_total_s
        } else {
            0.0
        };
        if self.state.inf_hist.total_weight() > 0.0 {
            report.inference_p50_latency_s = self.state.inf_hist.quantile(0.5);
            report.inference_p99_latency_s = self.state.inf_hist.quantile(0.99);
        }
        let (scale_ups, scale_downs) = policy.autoscale_counts();
        report.scale_ups = scale_ups;
        report.scale_downs = scale_downs;
        report.power_peak_w = self.state.peak_power_w;
        report.power_cap_w = self.cluster.power_cap_w();
        report.power_cap_attainment = if self.state.cap_intervals > 0 {
            self.state.cap_ok_intervals as f64 / self.state.cap_intervals as f64
        } else {
            1.0
        };
        report.joules_by_state = self.meter_total.joules_by_state();
        report.grams_co2 = self.meter_total.grams_co2();
        report.suspended_seconds = self.state.suspended_s;
        report.ftf_p99 = percentile(&self.state.ftf, 0.99);
        report.tier_attainment = self
            .state
            .tier_time
            .map(|(ok, total)| if total > 0.0 { ok / total } else { 1.0 });
        report
    }

    /// Ask the policy for a decision, apply + validate its delta, and
    /// account migrations, restart penalties and queueing delays.
    fn dispatch(&mut self, policy: &mut dyn Scheduler, event: ClusterEvent) -> Result<()> {
        let t0 = std::time::Instant::now();
        let decision = policy.on_event(&event, &self.cluster)?;
        let elapsed_s = t0.elapsed().as_secs_f64();
        self.state.decision_s += elapsed_s;
        self.state.decision_hist.record(elapsed_s * 1000.0, 1.0);
        self.report.events += 1;
        // under a power cap, down-clock or drop breaching ops instead of
        // failing the run; apply_delta still rejects anything that slips
        // through, transactionally
        let delta = self.cluster.trim_to_power_cap(&decision.delta);
        let outcome = self.cluster.apply_delta(&delta)?;
        self.report.migrations += outcome.moves;
        self.report.preemptions += outcome.suspended_jobs.len();
        // jobs restarting from scratch: migrated by this delta, resumed
        // from a parked state (they pay the same restart stall), plus
        // any failure-evicted job re-placed now (unplaced when the delta
        // applied, so migrated_jobs cannot see it — the sets are
        // disjoint). stall_job never double-charges an overlap, so a
        // job in two lists costs one stall.
        let mut restarted = outcome.migrated_jobs;
        restarted.extend(outcome.resumed_jobs);
        let replaced: Vec<JobId> = self
            .state
            .failure_evicted
            .iter()
            .copied()
            .filter(|j| self.cluster.placement.is_placed(*j))
            .collect();
        for j in &replaced {
            self.state.failure_evicted.remove(j);
        }
        restarted.extend(replaced);
        if self.migration_cost_s > 0.0 {
            let until = self.cluster.now() + self.migration_cost_s;
            for j in restarted {
                // stall_job returns the stall actually added, so
                // overlapping penalties extend rather than double-charge
                self.report.migration_stall_s += self.cluster.stall_job(j, until);
            }
        }
        // queueing delay: record the first time each job gets capacity
        let now = self.cluster.now();
        for j in self.cluster.active_job_ids() {
            if self.cluster.placement.is_placed(j) && !self.state.first_place.contains_key(&j) {
                self.state.first_place.insert(j, now);
                let arrived = self.state.arrival_time.get(&j).copied().unwrap_or(now);
                self.state.queue_wait_sum += now - arrived;
                self.state.queue_waits += 1;
            }
        }
        Ok(())
    }

    /// Advance work, energy and SLO accounting over [t0, t1] using the
    /// ground-truth throughputs of the current placement (the substrate
    /// "runs" the jobs; schedulers only ever see monitor samples).
    /// Jobs inside their migration-restart window make no progress.
    fn integrate(&mut self, t0: f64, t1: f64, policy: &mut dyn Scheduler) -> Result<()> {
        let dt = t1 - t0;
        if dt <= 0.0 {
            return Ok(());
        }
        // ground-truth throughput per job; inference jobs additionally
        // keep their per-replica rates for the M/M/c latency model
        let oracle = self.monitor.oracle().clone();
        let solo_cap = |a: AccelType| a.base_speed() / AccelType::V100.base_speed();
        let mut per_job: HashMap<JobId, f64> = HashMap::new();
        let mut replica_mus: HashMap<JobId, Vec<f64>> = HashMap::new();
        // per-instance relative loads, accumulated in the same pass
        // (same definition as `energy::placement_loads`: *un-scaled*
        // throughput over the type's solo capability — DVFS changes
        // power through the state curve, not the load argument)
        let mut loads: std::collections::BTreeMap<AccelId, f64> = Default::default();
        for (aid, combo) in self.cluster.placement.iter() {
            // ground truth scales with the host's DVFS frequency
            let freq = self.cluster.power_state(*aid).freq_scalar();
            let mut raw_total = 0.0;
            for j in combo.jobs() {
                let spec = self
                    .cluster
                    .job(j)
                    .ok_or_else(|| anyhow::anyhow!("placed job {j} is not registered"))?;
                let lookup = |id: JobId| self.cluster.job(id).cloned();
                let raw = oracle.throughput(spec, combo, aid.accel, &lookup);
                raw_total += raw;
                let t = freq * raw;
                *per_job.entry(j).or_default() += t;
                if spec.is_inference() {
                    replica_mus.entry(j).or_default().push(serving::service_rate(t));
                }
            }
            loads.insert(*aid, (raw_total / solo_cap(aid.accel).max(1e-9)).clamp(0.0, 1.0));
        }

        // energy: busy = only instances hosting work; total = in-service
        let busy: Vec<AccelId> = loads.keys().copied().collect();
        let in_service = self.cluster.available_accels();
        let gco2 = self.carbon.map_or(0.0, |c| c.intensity(t0));
        let cluster = &self.cluster;
        let state_of = |aid: AccelId| cluster.power_state(aid);
        self.meter_busy.accrue_states(t1, &busy, &state_of, &loads, gco2);
        self.meter_total.accrue_states(t1, &in_service, &state_of, &loads, gco2);
        // instantaneous measured draw: in-service instances at their real
        // loads. Since u ≤ 1, this never exceeds worst_case_watts, so the
        // transactional cap check implies peak ≤ cap at every interval.
        let watts: f64 = in_service
            .iter()
            .map(|aid| {
                let u = loads.get(aid).copied().unwrap_or(0.0);
                state_power_watts(aid.accel, cluster.power_state(*aid), u)
            })
            .sum();
        self.state.peak_power_w = self.state.peak_power_w.max(watts);
        self.state.cap_intervals += 1;
        if cluster.power_cap_w().map_or(true, |cap| watts <= cap + 1e-9) {
            self.state.cap_ok_intervals += 1;
        }

        // SLO + progress + completion (stalled jobs make no progress).
        // Training jobs burn work at their achieved throughput against a
        // throughput floor; inference jobs burn serving lifetime while
        // placed and are scored on M/M/c latency vs their SLO.
        let mut slo_violated = false;
        let ids = self.cluster.active_job_ids();
        let mut completed: Vec<JobId> = vec![];
        for id in ids {
            let achieved = per_job.get(&id).copied().unwrap_or(0.0);
            let stalled_until = self.cluster.stalled_until(id);
            let run_dt = (t1 - stalled_until.max(t0)).clamp(0.0, dt);
            // Parked jobs hold no instances: no progress, no SLO deficit
            // (parking is a deliberate policy action, not a violation),
            // but the parked time is reported and never counts as
            // attained in the per-tier score.
            let parked = self.cluster.is_suspended(id);
            if parked {
                self.state.suspended_s += dt;
            }
            let spec = self
                .cluster
                .job(id)
                .ok_or_else(|| anyhow::anyhow!("active job {id} has no spec"))?;
            let tier = spec.priority.index();
            if let Some(inf) = spec.inference {
                // serving capacity over the interval, de-rated by the
                // stalled fraction (a restarting replica serves nothing);
                // unplaced jobs have no replicas → infinite latency
                let mus = replica_mus.get(&id).cloned().unwrap_or_default();
                let frac = run_dt / dt;
                let eff: Vec<f64> = mus.iter().map(|m| m * frac).collect();
                let lam = spec.request_rate_at(t0);
                let lat = serving::mmc_sojourn(lam, &eff);
                let ok = lat <= inf.latency_slo_s;
                if let Some(tt) = self.state.tier_time.get_mut(tier) {
                    tt.1 += dt;
                    if ok && !parked {
                        tt.0 += dt;
                    }
                }
                self.state.inf_total_s += dt;
                if ok {
                    self.state.inf_attained_s += dt;
                }
                let e = self.state.inf_job_time.entry(id).or_insert((0.0, 0.0));
                e.1 += dt;
                if ok {
                    e.0 += dt;
                }
                self.state.inf_hist.record(lat, dt);
                self.report.replica_seconds += mus.len() as f64 * dt;
                let placed = !mus.is_empty();
                let j = self
                    .cluster
                    .job_mut(id)
                    .ok_or_else(|| anyhow::anyhow!("active job {id} vanished mid-interval"))?;
                if placed {
                    j.work -= run_dt;
                }
                if j.work <= 0.0 {
                    completed.push(id);
                }
            } else {
                let avg = achieved * run_dt / dt;
                let deficit = (spec.min_throughput - avg).max(0.0);
                let ok = deficit <= 1e-9;
                if let Some(tt) = self.state.tier_time.get_mut(tier) {
                    tt.1 += dt;
                    if ok && !parked {
                        tt.0 += dt;
                    }
                }
                if !ok && !parked {
                    self.report.slo_deficit += deficit * dt;
                    slo_violated = true;
                }
                let j = self
                    .cluster
                    .job_mut(id)
                    .ok_or_else(|| anyhow::anyhow!("active job {id} vanished mid-interval"))?;
                j.work -= achieved * run_dt;
                if j.work <= 0.0 {
                    completed.push(id);
                }
            }
        }
        if slo_violated {
            self.report.slo_violations += 1;
        }
        if !completed.is_empty() {
            self.cluster.advance_to(t1);
            for id in completed {
                let was_inference = self.cluster.job(id).map_or(false, |s| s.is_inference());
                self.cluster.remove_job(id);
                self.report.jobs_completed += 1;
                if was_inference {
                    self.report.inference_completed += 1;
                    if let Some(&(attained, total)) = self.state.inf_job_time.get(&id) {
                        if total > 0.0 && attained / total >= serving::SLO_MET_FRACTION {
                            self.report.inference_slo_met += 1;
                        }
                    }
                }
                let arrived = self.state.arrival_time.get(&id).copied().unwrap_or(0.0);
                self.state.jct_sum += t1 - arrived;
                if let Some(ideal) = self.state.ideal_jct.remove(&id) {
                    self.state.ftf.push((t1 - arrived) / ideal.max(1e-9));
                }
                self.dispatch(policy, ClusterEvent::JobCompleted { job: id })?;
            }
        }
        Ok(())
    }
}

/// Quantile of an unsorted sample by the nearest-rank rule (0.0 when
/// the sample is empty — reports print it unconditionally).
fn percentile(sample: &[f64], q: f64) -> f64 {
    if sample.is_empty() {
        return 0.0;
    }
    let mut v = sample.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let idx = ((v.len() as f64 * q).ceil() as usize).clamp(1, v.len()) - 1;
    v.get(idx).copied().unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::PlacementOp;
    use crate::coordinator::Decision;
    use crate::workload::{Combo, ModelFamily, TraceConfig};

    struct FirstFit;
    impl Scheduler for FirstFit {
        fn name(&self) -> &str {
            "firstfit"
        }
        fn on_event(&mut self, _event: &ClusterEvent, cluster: &Cluster) -> Result<Decision> {
            // places waiting jobs on every event, including MonitorTick,
            // so jobs restored from a snapshot (no Arrival event) place
            let mut delta = crate::cluster::PlacementDelta::new();
            let mut free: Vec<AccelId> = cluster
                .available_accels()
                .into_iter()
                .filter(|a| cluster.placement.combo_on(*a).is_none())
                .collect();
            for j in cluster.active_job_ids() {
                if !cluster.placement.is_placed(j) {
                    if let Some(a) = free.pop() {
                        delta.push(PlacementOp::Assign {
                            accel: a,
                            combo: Combo::Solo(j),
                        });
                    }
                }
            }
            Ok(Decision::apply(delta))
        }
    }

    fn job(id: u32, work: f64) -> JobSpec {
        JobSpec {
            id: JobId(id),
            family: ModelFamily::ResNet18,
            batch_size: 32,
            replication: 1,
            min_throughput: 0.0,
            distributability: 1,
            work,
            priority: Default::default(),
            elastic: false,
            inference: None,
        }
    }

    fn core(seed: u64) -> GoghCore {
        GoghCore::new(ClusterSpec::balanced(1), ThroughputOracle::new(seed), 0.0, 15.0, 1)
            .unwrap()
    }

    #[test]
    fn advance_to_matches_run_between_events() {
        // drive the same one-job workload with run() and with many small
        // advance_to() increments: identical completions and energy.
        let mk = || {
            let mut c = core(3);
            c.submit(1.0, job(0, 40.0));
            c
        };
        let mut sim = mk();
        sim.run(&mut FirstFit, 3600.0).unwrap();
        let sim_report = sim.report(&FirstFit);

        let mut live = mk();
        live.start_monitor();
        let mut t = 0.0;
        while !live.drained() || live.pending_arrivals() > 0 {
            t += 0.5;
            live.advance_to(t, &mut FirstFit).unwrap();
            assert!(t < 500.0, "live drive failed to drain");
        }
        let live_report = live.report(&FirstFit);
        assert_eq!(live_report.jobs_completed, sim_report.jobs_completed);
        assert_eq!(live_report.jobs_total, sim_report.jobs_total);
        // completion lands on a 0.5 s boundary instead of an event
        // boundary, so JCT/energy agree only approximately
        assert!((live_report.mean_jct - sim_report.mean_jct).abs() < 16.0);
    }

    #[test]
    fn submit_counts_totals_like_a_trace() {
        let oracle = ThroughputOracle::new(2);
        let cfg = TraceConfig {
            n_jobs: 6,
            mean_interarrival_s: 10.0,
            mean_work_s: 50.0,
            ..Default::default()
        };
        let trace = Trace::generate(&cfg, &oracle);
        let mut c = GoghCore::new(ClusterSpec::balanced(2), oracle, 0.0, 15.0, 1).unwrap();
        c.load_trace(&trace);
        assert_eq!(c.pending_arrivals(), 6);
        c.run(&mut FirstFit, 24.0 * 3600.0).unwrap();
        let report = c.report(&FirstFit);
        assert_eq!(report.jobs_total, trace.n_jobs());
        assert_eq!(report.jobs_completed, 6);
        assert!(c.drained());
    }

    #[test]
    fn pending_events_excludes_monitor_tick_and_orders() {
        let mut c = core(4);
        c.start_monitor();
        c.submit(9.0, job(1, 10.0));
        c.submit(2.0, job(0, 10.0));
        c.cancel(5.0, JobId(0));
        let pending = c.pending_events();
        assert_eq!(pending.len(), 3);
        assert_eq!(pending[0].0, 2.0);
        assert!(matches!(pending[1].1, CoreEvent::Cancel(JobId(0))));
        assert_eq!(pending[2].0, 9.0);
    }

    #[test]
    fn restore_job_keeps_arrival_time_for_jct() {
        let mut c = core(5);
        c.cluster_mut().advance_to(100.0);
        c.restore_job(job(7, 5.0), 40.0);
        c.restore_counters(3, 2, 0);
        c.start_monitor();
        // job completes at the first monitor tick after restore
        c.run(&mut FirstFit, 3600.0).unwrap();
        let report = c.report(&FirstFit);
        assert_eq!(report.jobs_total, 3);
        assert_eq!(report.jobs_completed, 3);
        // JCT measured from the restored arrival time (40), not from 0
        // or from the restore point: completion is ≥ 105 ⇒ jct ≥ 65
        assert!(report.mean_jct >= 65.0 / 3.0, "{}", report.mean_jct);
    }

    #[test]
    fn power_cap_trims_decisions_and_peak_stays_under_cap() {
        use crate::power::PowerState;
        // two V100s under a 250 W cap: both busy at nominal would draw
        // 500 W worst-case, so the trim layer must down-clock and
        // serialize instead of failing the run
        let spec = ClusterSpec::mix(&[(AccelType::V100, 2)]);
        let mut c = GoghCore::new(spec, ThroughputOracle::new(9), 0.0, 15.0, 1)
            .unwrap()
            .with_options(EngineOptions::new().with_power_cap(Some(250.0)));
        c.submit(1.0, job(0, 40.0));
        c.submit(2.0, job(1, 40.0));
        c.run(&mut FirstFit, 3600.0).unwrap();
        let report = c.report(&FirstFit);
        assert_eq!(report.jobs_completed, 2);
        assert_eq!(report.power_cap_w, Some(250.0));
        assert!(report.power_peak_w > 0.0, "{}", report.power_peak_w);
        assert!(report.power_peak_w <= 250.0, "{}", report.power_peak_w);
        assert_eq!(report.power_cap_attainment, 1.0);
        // the down-clocked host accrued energy in the low bucket
        assert!(report.joules_by_state[PowerState::Low.index()] > 0.0);
        assert_eq!(report.grams_co2, 0.0); // no carbon signal attached
    }

    #[test]
    fn suspend_counts_preemption_and_resume_charges_stall() {
        struct ParkOnce {
            parked: bool,
            resumed: bool,
        }
        impl Scheduler for ParkOnce {
            fn name(&self) -> &str {
                "parkonce"
            }
            fn on_event(&mut self, event: &ClusterEvent, cluster: &Cluster) -> Result<Decision> {
                let mut delta = crate::cluster::PlacementDelta::new();
                match event {
                    ClusterEvent::JobArrived { job } => delta.push(PlacementOp::Assign {
                        accel: cluster.spec.accels[0],
                        combo: Combo::Solo(*job),
                    }),
                    ClusterEvent::MonitorTick { .. } if !self.parked => {
                        self.parked = true;
                        delta.push(PlacementOp::Suspend { job: JobId(0) });
                    }
                    ClusterEvent::MonitorTick { .. } if !self.resumed => {
                        self.resumed = true;
                        delta.push(PlacementOp::Resume {
                            job: JobId(0),
                            accel: cluster.spec.accels[1],
                        });
                    }
                    _ => {}
                }
                Ok(Decision::apply(delta))
            }
        }
        let mut c = core(13).with_options(EngineOptions::new().with_migration_cost(5.0));
        c.submit(1.0, job(0, 200.0));
        let mut policy = ParkOnce {
            parked: false,
            resumed: false,
        };
        c.run(&mut policy, 24.0 * 3600.0).unwrap();
        let report = c.report(&policy);
        assert_eq!(report.jobs_completed, 1);
        assert_eq!(report.preemptions, 1);
        // parked from the first tick (t=15) to the second (t=30)
        assert!(
            (report.suspended_seconds - 15.0).abs() < 1e-6,
            "{}",
            report.suspended_seconds
        );
        // the resume paid the 5 s restart stall
        assert!(report.migration_stall_s >= 5.0 - 1e-9, "{}", report.migration_stall_s);
        // parked seconds never count as attained for the job's tier
        assert!(report.tier_attainment[1] < 1.0, "{}", report.tier_attainment[1]);
        // the parked job finished later than its exclusive ideal
        assert!(report.ftf_p99 > 1.0, "{}", report.ftf_p99);
    }

    #[test]
    fn carbon_signal_accrues_emissions() {
        let signal = crate::power::CarbonSignal {
            base_gco2_per_kwh: 420.0,
            amplitude: 0.35,
            phase_s: 0.0,
        };
        let mut c = core(11).with_options(EngineOptions::new().with_carbon(Some(signal)));
        c.submit(1.0, job(0, 40.0));
        c.run(&mut FirstFit, 3600.0).unwrap();
        let report = c.report(&FirstFit);
        assert_eq!(report.jobs_completed, 1);
        assert!(report.grams_co2 > 0.0);
        // sanity: grams ≈ joules × intensity bounds (0.65–1.35 × base)
        let j = report.total_energy_joules;
        assert!(report.grams_co2 >= 0.65 * 420.0 * j / 3.6e6 - 1e-9);
        assert!(report.grams_co2 <= 1.35 * 420.0 * j / 3.6e6 + 1e-9);
    }
}
