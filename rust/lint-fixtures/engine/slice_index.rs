// BAD: literal slice index in the engine (panic-slice-index). An empty
// placement panics the event loop; use .first() / .get().

pub fn first_accel(accels: &[u32]) -> u32 {
    accels[0]
}
