// BAD: hash containers on a decision path (determinism-hash-container).
// Iteration order is seeded per process; float accumulation order (and
// therefore energy totals and placements) would differ run to run.

use std::collections::{HashMap, HashSet};

pub fn total_load(loads: &HashMap<u32, f64>, busy: &HashSet<u32>) -> f64 {
    loads
        .iter()
        .filter(|(id, _)| busy.contains(id))
        .map(|(_, u)| u)
        .sum()
}
