// BAD: panicking extraction in the daemon (panic-unwrap). A panic here
// kills goghd and loses the cluster; return a protocol error envelope.

pub fn job_id(line: &str) -> u32 {
    let parsed: Option<u32> = line.trim().parse().ok();
    let id = parsed.unwrap();
    let doubled = line.trim().parse::<u32>().expect("numeric job id");
    id + doubled
}
