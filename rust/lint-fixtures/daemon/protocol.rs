// BAD: error code outside the documented closed set
// (protocol-error-code). Clients match on codes — a new one is a
// protocol change that must land in ERROR_CODES + docs/PROTOCOL.md.

pub struct ProtoError;

impl ProtoError {
    pub fn new(_code: &'static str, _message: String) -> Self {
        ProtoError
    }
}

pub fn reject(detail: String) -> ProtoError {
    ProtoError::new("quota_exceeded", detail)
}
