// BAD: panic macros in the daemon (panic-macro).

pub fn dispatch(cmd: &str) -> u32 {
    match cmd {
        "queue" => 1,
        "status" => 2,
        "drain" => unimplemented!("drain not wired yet"),
        _ => panic!("unknown cmd {cmd}"),
    }
}
