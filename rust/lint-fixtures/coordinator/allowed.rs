// GOOD: a correctly allow-listed exemption — gogh-lint must report
// nothing for this file.

pub struct SolveStats {
    pub solve_seconds: f64,
}

pub fn timed_solve(stats: &mut SolveStats) {
    // gogh-lint: allow(determinism-wall-clock, timing statistic only; never branches on it)
    let t0 = std::time::Instant::now();
    stats.solve_seconds += t0.elapsed().as_secs_f64();
}
