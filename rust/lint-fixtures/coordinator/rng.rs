// BAD: ambient randomness (rng-source). Every experiment must be
// exactly reproducible from its seed via util/rng.rs streams.

use std::collections::hash_map::RandomState;

pub fn jitter() -> u64 {
    let state = RandomState::new();
    let sample = rand::thread_rng();
    let _ = (state, sample);
    0
}
