// BAD: suppressions that don't carry their weight (bad-suppression).

// gogh-lint: allow(determinism-wall-clock)
pub fn missing_reason() -> std::time::Instant {
    std::time::Instant::now()
}

// gogh-lint: allow(no-such-rule, a reason for a rule that does not exist)
pub fn unknown_rule() {}
