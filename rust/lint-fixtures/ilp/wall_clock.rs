// BAD: wall-clock read on a decision path (determinism-wall-clock).
// A solver cutoff keyed to real time makes placements irreproducible.

pub fn solve_with_deadline() -> f64 {
    let start = std::time::Instant::now();
    let epoch = std::time::SystemTime::now();
    let _ = epoch;
    start.elapsed().as_secs_f64()
}
