//! Figure 3 — combined validation MAE / loss of every P1 × P2 pair.
//!
//!     cargo bench --bench fig3_pairs
//!
//! The two-phase pipeline of the paper: P1 produces initial estimates
//! for a job on two accelerator types; the cluster measures one of
//! them; P2 transfers the observation to the other type. The reported
//! metric is the error of P2's refined estimate against ground truth,
//! over validation-config jobs.
//!
//! Paper shape: RNN→FF is the best pair, Transformer→FF the runner-up.

include!("bench_util.rs");

use gogh::runtime::{dataset::PipelineItem, DatasetBuilder, Engine, Estimator};
use gogh::workload::encoding::{p2_row, PSI_EMPTY};
use gogh::workload::ThroughputOracle;

const SEED: u64 = 29;
const N_TRAIN: usize = 6000;
const N_PIPE: usize = 1200;
const STEPS: usize = 400;

fn main() -> gogh::Result<()> {
    let engine = Engine::load("artifacts")?;
    let oracle = ThroughputOracle::new(SEED);
    let builder = DatasetBuilder::new(&oracle, SEED);
    let (train_cfgs, val_cfgs, _) = gogh::runtime::split_universe(SEED);

    // train all six networks once
    let mut p1s = vec![];
    let mut p2s = vec![];
    let p1_split = builder.build_split("p1", N_TRAIN, 16);
    let p2_split = builder.build_split("p2", N_TRAIN, 16);
    for arch in ["ff", "rnn", "transformer"] {
        let mut e1 = Estimator::new(&engine, &format!("p1_{arch}"))?;
        train_estimator(&mut e1, &p1_split.train, STEPS, SEED)?;
        p1s.push((arch, e1));
        let mut e2 = Estimator::new(&engine, &format!("p2_{arch}"))?;
        train_estimator(&mut e2, &p2_split.train, STEPS, SEED)?;
        p2s.push((arch, e2));
    }

    let items: Vec<PipelineItem> = builder.pipeline_items(N_PIPE, &val_cfgs, &train_cfgs, 5);
    println!("# Figure 3 — combined validation metrics of P1→P2 pipelines");
    println!("# {N_PIPE} pipeline items over validation configs");
    println!(
        "{:<26} {:>12} {:>12} {:>14}",
        "pipeline", "val_mae", "val_loss", "p1_only_mae"
    );

    let mut results: Vec<(String, f64, f64, f64)> = vec![];
    for (a1name, p1) in p1s.iter_mut() {
        // P1 estimates for both accel types of every item (two batched calls)
        let rows_a1: Vec<Vec<f32>> = items.iter().map(|i| i.p1_row_a1.clone()).collect();
        let rows_a2: Vec<Vec<f32>> = items.iter().map(|i| i.p1_row_a2.clone()).collect();
        let est_a1 = p1.predict(&rows_a1)?;
        let est_a2 = p1.predict(&rows_a2)?;
        // P1-only error: its a2 estimate without refinement
        let p1_only_mae: f64 = items
            .iter()
            .zip(&est_a2)
            .map(|(it, e)| (e[0] - it.truth_a2).abs() as f64)
            .sum::<f64>()
            / items.len() as f64;

        for (a2name, p2) in p2s.iter_mut() {
            let p2_rows: Vec<Vec<f32>> = items
                .iter()
                .enumerate()
                .map(|(k, it)| {
                    p2_row(
                        &it.psi_j1,
                        &PSI_EMPTY,
                        it.a1,
                        it.a2,
                        est_a1[k][0],
                        0.0,
                        it.meas_a1,
                        0.0,
                        est_a2[k][0],
                        0.0,
                    )
                    .to_vec()
                })
                .collect();
            let refined = p2.predict(&p2_rows)?;
            let (mut abs, mut sq) = (0.0f64, 0.0f64);
            for (it, r) in items.iter().zip(&refined) {
                let e = (r[0] - it.truth_a2) as f64;
                abs += e.abs();
                sq += e * e;
            }
            let mae = abs / items.len() as f64;
            let loss = sq / items.len() as f64;
            results.push((format!("{a1name}->{a2name}"), mae, loss, p1_only_mae));
        }
    }
    results.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for (name, mae, loss, p1only) in &results {
        println!("{:<26} {:>12.5} {:>12.6} {:>14.5}", name, mae, loss, p1only);
    }
    println!("\n# best pipeline: {}", results[0].0);
    Ok(())
}
