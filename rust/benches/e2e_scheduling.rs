//! End-to-end scheduling comparison (EXPERIMENTS.md §E2E): energy, SLO
//! satisfaction, completion time, migrations and per-event decision
//! latency for GOGH vs baselines on identical traces, plus GOGH's
//! online estimation MAE (the paper's "prediction errors as low as 5%"
//! headline), the incremental-vs-full arrival-path solver cost, and the
//! shard-parallel scale bench on the `large` preset (≥1024 accelerators,
//! ≥50k trace events; set GOGH_SCALE_JOBS=N for a truncated dry run).
//!
//!     cargo bench --bench e2e_scheduling
//!
//! Set GOGH_BENCH_JSON=<path> to emit a machine-readable
//! `BENCH_e2e.json` record (mean decision ms on the P=1 leg, explored
//! B&B nodes, peak RSS) — CI uploads it as an artifact and gates mean
//! decision latency against `.github/bench_baseline_e2e.json`.

include!("bench_util.rs");

use gogh::baselines::{GreedyScheduler, OracleScheduler, RandomScheduler};
use gogh::cluster::ClusterSpec;
use gogh::config::ExperimentConfig;
use gogh::coordinator::{GoghOptions, GoghScheduler, SimDriver};
use gogh::engine::EngineOptions;
use gogh::metrics::SchedulerComparison;
use gogh::runtime::Engine;
use gogh::workload::{ThroughputOracle, Trace};

const SEEDS: [u64; 3] = [11, 12, 13];

fn main() -> gogh::Result<()> {
    match Engine::load("artifacts") {
        Ok(engine) => comparison(&engine)?,
        Err(err) => println!("# skipping the estimator-backed comparison (no PJRT engine: {err})"),
    }
    scale_bench()?;
    huge_bench()?;
    mixed_bench()
}

/// Fleet-scale decision path on the `huge` preset (≥10k accelerators,
/// two-level topology routing, estimator-free GOGH): the p99 decision
/// latency is the headline number. GOGH_HUGE_JOBS=N truncates;
/// GOGH_BENCH_JSON_HUGE=<path> emits the gated `e2e_huge` BENCH record.
fn huge_bench() -> gogh::Result<()> {
    let mut cfg = ExperimentConfig::preset("huge")?;
    if let Some(n) = std::env::var("GOGH_HUGE_JOBS").ok().and_then(|s| s.parse().ok()) {
        cfg.trace.n_jobs = n;
    }
    println!(
        "\n# Huge: two-level topology decision path, {} accels, {} jobs, \
         {} groups x {} shards (estimator-free GOGH)",
        cfg.cluster.accel_mix.iter().map(|(_, n)| n).sum::<u32>(),
        cfg.trace.n_jobs,
        cfg.gogh.topology_groups,
        cfg.gogh.shards
    );
    let oracle = cfg.build_oracle()?;
    let trace = Trace::generate(&cfg.trace, &oracle);
    println!("  trace: {} events ({} arrivals)", trace.len(), trace.n_jobs());
    let mut driver = SimDriver::new(
        ClusterSpec::mix(&cfg.cluster.accel_mix),
        oracle.clone(),
        trace,
        cfg.noise_sigma,
        cfg.monitor_interval_s,
        cfg.seed,
    )?
    .with_options(EngineOptions::new().with_migration_cost(cfg.migration_cost_s));
    let mut sched = GoghScheduler::without_engine(&oracle, GoghOptions::from_config(&cfg))?;
    let t0 = Instant::now();
    let report = driver.run(&mut sched)?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = sched.solver_stats();
    let cache = sched.cache_stats();
    let routed: usize = sched.shard_stats().iter().map(|s| s.routed).sum();
    println!(
        "  mean {:.3} ms/event, p99 {:.3} ms over {} events; completed {}/{}; \
         {} arrivals routed; cache {:.1}% hit ({} hits / {} misses); wall {:.0} s",
        report.mean_decision_ms,
        report.p99_decision_ms,
        report.events,
        report.jobs_completed,
        report.jobs_total,
        routed,
        100.0 * cache.hit_rate(),
        cache.hits,
        cache.misses,
        wall,
    );
    assert!(report.jobs_completed > 0, "huge leg completed nothing");
    if let Ok(path) = std::env::var("GOGH_BENCH_JSON_HUGE") {
        let record = gogh::metrics::BenchRecord {
            bench: "e2e_huge".to_string(),
            jobs: report.jobs_total,
            mean_decision_ms: report.mean_decision_ms,
            p99_decision_ms: report.p99_decision_ms,
            explored_nodes: stats.full_nodes + stats.incremental_nodes,
            peak_rss_bytes: gogh::metrics::peak_rss_bytes(),
        };
        record.write(std::path::Path::new(&path))?;
        println!("bench record written to {path}: {}", record.to_json());
    }
    Ok(())
}

/// Mixed train+infer decision path on the `mixed` preset (estimator-free
/// GOGH, like the scale bench — this leg gates the latency-ILP and
/// autoscaler cost, not the estimators). GOGH_MIXED_JOBS=N truncates;
/// GOGH_BENCH_JSON_MIXED=<path> emits the gated BENCH record.
fn mixed_bench() -> gogh::Result<()> {
    let mut cfg = ExperimentConfig::preset("mixed")?;
    if let Some(n) = std::env::var("GOGH_MIXED_JOBS").ok().and_then(|s| s.parse().ok()) {
        cfg.trace.n_jobs = n;
    }
    println!(
        "\n# Mixed: train+infer decision path, {} jobs ({}% inference, estimator-free GOGH)",
        cfg.trace.n_jobs,
        (100.0 * cfg.trace.inference_fraction) as u32
    );
    let oracle = cfg.build_oracle()?;
    let trace = Trace::generate(&cfg.trace, &oracle);
    let mut driver = SimDriver::new(
        ClusterSpec::mix(&cfg.cluster.accel_mix),
        oracle.clone(),
        trace,
        cfg.noise_sigma,
        cfg.monitor_interval_s,
        cfg.seed,
    )?
    .with_options(EngineOptions::new().with_migration_cost(cfg.migration_cost_s));
    let mut sched = GoghScheduler::without_engine(&oracle, GoghOptions::from_config(&cfg))?;
    let t0 = Instant::now();
    let report = driver.run(&mut sched)?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = sched.solver_stats();
    println!(
        "  {:.3} ms/event over {} events; completed {}/{}; inference {}/{} met SLO \
         (attainment {:.3}, {} scale-ups, {} scale-downs); wall {:.0} s",
        report.mean_decision_ms,
        report.events,
        report.jobs_completed,
        report.jobs_total,
        report.inference_slo_met,
        report.inference_total,
        report.inference_attainment,
        report.scale_ups,
        report.scale_downs,
        wall,
    );
    assert!(report.jobs_completed > 0, "mixed leg completed nothing");
    assert!(report.inference_total > 0, "mixed leg generated no inference jobs");
    if let Ok(path) = std::env::var("GOGH_BENCH_JSON_MIXED") {
        let record = gogh::metrics::BenchRecord {
            bench: "e2e_mixed".to_string(),
            jobs: report.jobs_total,
            mean_decision_ms: report.mean_decision_ms,
            p99_decision_ms: report.p99_decision_ms,
            explored_nodes: stats.full_nodes + stats.incremental_nodes,
            peak_rss_bytes: gogh::metrics::peak_rss_bytes(),
        };
        record.write(std::path::Path::new(&path))?;
        println!("bench record written to {path}: {}", record.to_json());
    }
    Ok(())
}

/// Shard-parallel decision path on the `large` preset: identical trace
/// at P = 1/2/4/8 shards; the sharded legs must beat the unsharded
/// per-event decision latency (P = 1 runs the single-threaded pre-shard
/// path, so it doubles as the baseline).
fn scale_bench() -> gogh::Result<()> {
    let base = ExperimentConfig::large_scale();
    let jobs_override: Option<usize> =
        std::env::var("GOGH_SCALE_JOBS").ok().and_then(|s| s.parse().ok());
    let n_jobs = jobs_override.unwrap_or(base.trace.n_jobs);
    println!(
        "\n# Scale: sharded decision path, {} accels, {} jobs (estimator-free GOGH)",
        base.cluster.accel_mix.iter().map(|(_, n)| n).sum::<u32>(),
        n_jobs
    );
    let mut latency: Vec<(usize, f64)> = vec![];
    // the P=1 leg's numbers are the gated record: single-threaded, so
    // nodes are deterministic and the latency is host-load-insensitive
    let mut gated = gogh::metrics::BenchRecord {
        bench: "e2e_scheduling".to_string(),
        jobs: n_jobs,
        ..Default::default()
    };
    for shards in [1usize, 2, 4, 8] {
        let mut cfg = base.clone();
        cfg.gogh.shards = shards;
        cfg.trace.n_jobs = n_jobs;
        let oracle = cfg.build_oracle()?;
        let trace = Trace::generate(&cfg.trace, &oracle);
        println!(
            "  [P={shards}] trace: {} events ({} arrivals)",
            trace.len(),
            trace.n_jobs()
        );
        let mut driver = SimDriver::new(
            ClusterSpec::mix(&cfg.cluster.accel_mix),
            oracle.clone(),
            trace,
            cfg.noise_sigma,
            cfg.monitor_interval_s,
            cfg.seed,
        )?
        .with_options(EngineOptions::new().with_migration_cost(cfg.migration_cost_s));
        let mut sched = GoghScheduler::without_engine(&oracle, GoghOptions::from_config(&cfg))?;
        let t0 = Instant::now();
        let report = driver.run(&mut sched)?;
        let wall = t0.elapsed().as_secs_f64();
        let stats = sched.solver_stats();
        let cache = sched.cache_stats();
        println!(
            "  [P={shards}] {:.3} ms/event over {} events; completed {}/{}; \
             {} full / {} incremental solves; cache {:.1}% hit; wall {:.0} s",
            report.mean_decision_ms,
            report.events,
            report.jobs_completed,
            report.jobs_total,
            stats.full_solves,
            stats.incremental_solves,
            100.0 * cache.hit_rate(),
            wall,
        );
        for (i, s) in sched.shard_stats().iter().enumerate() {
            if s.solves > 0 {
                println!(
                    "      shard {i}: {} solves ({:.1} nodes/solve), {} routed",
                    s.solves,
                    s.mean_nodes(),
                    s.routed
                );
            }
        }
        assert!(report.jobs_completed > 0, "P={shards}: nothing completed");
        if shards == 1 {
            gated.mean_decision_ms = report.mean_decision_ms;
            gated.p99_decision_ms = report.p99_decision_ms;
            gated.explored_nodes = stats.full_nodes + stats.incremental_nodes;
        }
        latency.push((shards, report.mean_decision_ms));
    }
    if let Ok(path) = std::env::var("GOGH_BENCH_JSON") {
        gated.peak_rss_bytes = gogh::metrics::peak_rss_bytes();
        gated.write(std::path::Path::new(&path))?;
        println!("bench record written to {path}: {}", gated.to_json());
    }
    let unsharded = latency[0].1;
    let best_wide = latency
        .iter()
        .filter(|(p, _)| *p >= 4)
        .map(|(_, l)| *l)
        .fold(f64::INFINITY, f64::min);
    println!(
        "per-event decision latency: P=1 {:.3} ms vs best P>=4 {:.3} ms ({:.2}x)",
        unsharded,
        best_wide,
        unsharded / best_wide.max(1e-12)
    );
    // The acceptance assertion needs real parallelism AND the full-size
    // trace: on a 1-3 core host oversubscribed shard workers can't beat
    // the single-threaded path, and on a GOGH_SCALE_JOBS-truncated run
    // (e.g. the CI bench gate's 300-job smoke) per-arrival thread-spawn
    // overhead can dominate the tiny solves — report instead of
    // panicking in both cases.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if jobs_override.is_some() {
        println!("(latency assertion skipped: GOGH_SCALE_JOBS-truncated run)");
    } else if cores < 4 {
        println!("(latency assertion skipped: only {cores} cores available)");
    } else {
        assert!(
            best_wide < unsharded,
            "sharded (P>=4) decision path is not faster: {best_wide} vs {unsharded} ms/event"
        );
    }
    Ok(())
}

fn comparison(engine: &Engine) -> gogh::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.trace.n_jobs = 30;
    cfg.trace.mean_interarrival_s = 40.0;
    cfg.trace.mean_work_s = 800.0;

    println!("# E2E scheduler comparison, mean over seeds {SEEDS:?}");
    let mut agg: Vec<(String, Vec<gogh::metrics::RunReport>)> = vec![];
    for policy in ["random", "greedy", "gogh", "oracle-ilp"] {
        let mut reports = vec![];
        for &seed in &SEEDS {
            cfg.seed = seed;
            cfg.trace.seed = seed;
            let oracle = ThroughputOracle::new(seed);
            let trace = Trace::generate(&cfg.trace, &oracle);
            let mut driver = SimDriver::new(
                ClusterSpec::mix(&cfg.cluster.accel_mix),
                oracle.clone(),
                trace,
                cfg.noise_sigma,
                cfg.monitor_interval_s,
                seed,
            )?;
            let report = match policy {
                "random" => driver.run(&mut RandomScheduler::new(seed))?,
                "greedy" => driver.run(&mut GreedyScheduler::new())?,
                "oracle-ilp" => {
                    driver.run(&mut OracleScheduler::new(oracle, cfg.optimizer.clone()))?
                }
                _ => {
                    let mut sched = GoghScheduler::new(
                        engine,
                        &oracle,
                        GoghOptions {
                            estimator: cfg.estimator.clone(),
                            optimizer: cfg.optimizer.clone(),
                            seed,
                            ..Default::default()
                        },
                    )?;
                    driver.run(&mut sched)?
                }
            };
            reports.push(report);
        }
        agg.push((policy.to_string(), reports));
    }

    let mut table = SchedulerComparison::default();
    for (name, reports) in &agg {
        let n = reports.len() as f64;
        let mut mean = gogh::metrics::RunReport {
            scheduler: name.clone(),
            jobs_total: reports[0].jobs_total,
            ..Default::default()
        };
        for r in reports {
            mean.energy_joules += r.energy_joules / n;
            mean.total_energy_joules += r.total_energy_joules / n;
            mean.jobs_completed += r.jobs_completed / reports.len();
            mean.slo_deficit += r.slo_deficit / n;
            mean.slo_violations += r.slo_violations / reports.len();
            mean.migrations += r.migrations / reports.len();
            mean.mean_jct += r.mean_jct / n;
            mean.sim_seconds += r.sim_seconds / n;
            mean.mean_solve_ms += r.mean_solve_ms / n;
            mean.mean_decision_ms += r.mean_decision_ms / n;
            mean.mean_queue_s += r.mean_queue_s / n;
        }
        mean.events = reports.iter().map(|r| r.events).sum::<usize>() / reports.len();
        mean.estimation_mae = {
            let maes: Vec<f64> = reports.iter().filter_map(|r| r.estimation_mae).collect();
            (!maes.is_empty()).then(|| maes.iter().sum::<f64>() / maes.len() as f64)
        };
        table.push(mean);
    }
    println!("{}", table.table());
    println!("energy ratios vs random:");
    for (name, ratio) in table.energy_ratios() {
        println!("  {name:<12} {ratio:.3}x");
    }
    println!("per-event decision latency:");
    for r in &table.reports {
        println!(
            "  {:<12} {:>8.3} ms/event over {} events",
            r.scheduler, r.mean_decision_ms, r.events
        );
    }
    for r in &table.reports {
        if let Some(mae) = r.estimation_mae {
            println!("{} estimation MAE: {:.4}", r.scheduler, mae);
        }
        if r.mean_solve_ms > 0.0 {
            println!("{} mean ILP solve: {:.1} ms", r.scheduler, r.mean_solve_ms);
        }
    }

    // ---- incremental arrival path vs full re-solve -------------------
    // At |J| ≥ 16 the bounded neighborhood ILP must explore no more
    // nodes per arrival solve than the full Problem-1 re-solve.
    println!("\n# GOGH incremental arrival path vs full re-solve (|J| = 16)");
    let mut icfg = ExperimentConfig::default();
    icfg.trace.n_jobs = 16;
    icfg.trace.mean_interarrival_s = 25.0;
    icfg.trace.mean_work_s = 1200.0;
    icfg.seed = 11;
    icfg.trace.seed = 11;
    let mut mean_nodes = [0.0f64; 2];
    for (slot, (label, full_every, neighborhood)) in
        [("incremental", 8usize, 4usize), ("full-resolve", 1, 0)].iter().enumerate()
    {
        let oracle = ThroughputOracle::new(icfg.seed);
        let trace = Trace::generate(&icfg.trace, &oracle);
        let mut driver = SimDriver::new(
            ClusterSpec::mix(&icfg.cluster.accel_mix),
            oracle.clone(),
            trace,
            icfg.noise_sigma,
            icfg.monitor_interval_s,
            icfg.seed,
        )?;
        let mut sched = GoghScheduler::new(
            engine,
            &oracle,
            GoghOptions {
                estimator: icfg.estimator.clone(),
                optimizer: icfg.optimizer.clone(),
                full_resolve_every: *full_every,
                neighborhood: *neighborhood,
                seed: icfg.seed,
                ..Default::default()
            },
        )?;
        let report = driver.run(&mut sched)?;
        let stats = sched.solver_stats();
        mean_nodes[slot] = if *neighborhood > 0 {
            stats.mean_incremental_nodes()
        } else {
            stats.mean_full_nodes()
        };
        println!(
            "  {label:<13} {:>3} incremental solves ({:>7.1} nodes/solve), \
             {:>3} full solves ({:>7.1} nodes/solve), {:>7.3} ms/event",
            stats.incremental_solves,
            stats.mean_incremental_nodes(),
            stats.full_solves,
            stats.mean_full_nodes(),
            report.mean_decision_ms,
        );
    }
    assert!(
        mean_nodes[0] <= mean_nodes[1],
        "incremental path explored MORE nodes per solve than full re-solve: {} vs {}",
        mean_nodes[0],
        mean_nodes[1]
    );
    println!(
        "incremental/full nodes per solve: {:.1}/{:.1}",
        mean_nodes[0], mean_nodes[1]
    );
    Ok(())
}
