//! End-to-end scheduling comparison (EXPERIMENTS.md §E2E): energy, SLO
//! satisfaction, completion time and migrations for GOGH vs baselines
//! on identical traces, plus GOGH's online estimation MAE (the paper's
//! "prediction errors as low as 5%" headline).
//!
//!     cargo bench --bench e2e_scheduling

include!("bench_util.rs");

use gogh::baselines::{GreedyScheduler, OracleScheduler, RandomScheduler};
use gogh::cluster::ClusterSpec;
use gogh::config::ExperimentConfig;
use gogh::coordinator::{GoghOptions, GoghScheduler, SimDriver};
use gogh::metrics::SchedulerComparison;
use gogh::runtime::Engine;
use gogh::workload::{ThroughputOracle, Trace};

const SEEDS: [u64; 3] = [11, 12, 13];

fn main() -> gogh::Result<()> {
    let engine = Engine::load("artifacts")?;
    let mut cfg = ExperimentConfig::default();
    cfg.trace.n_jobs = 30;
    cfg.trace.mean_interarrival_s = 40.0;
    cfg.trace.mean_work_s = 800.0;

    println!("# E2E scheduler comparison, mean over seeds {SEEDS:?}");
    let mut agg: Vec<(String, Vec<gogh::metrics::RunReport>)> = vec![];
    for policy in ["random", "greedy", "gogh", "oracle-ilp"] {
        let mut reports = vec![];
        for &seed in &SEEDS {
            cfg.seed = seed;
            cfg.trace.seed = seed;
            let oracle = ThroughputOracle::new(seed);
            let trace = Trace::generate(&cfg.trace, &oracle);
            let mut driver = SimDriver::new(
                ClusterSpec::mix(&cfg.cluster.accel_mix),
                oracle.clone(),
                trace,
                cfg.noise_sigma,
                cfg.monitor_interval_s,
                seed,
            );
            let report = match policy {
                "random" => driver.run(&mut RandomScheduler::new(seed))?,
                "greedy" => driver.run(&mut GreedyScheduler::new())?,
                "oracle-ilp" => {
                    driver.run(&mut OracleScheduler::new(oracle, cfg.optimizer.clone()))?
                }
                _ => {
                    let mut sched = GoghScheduler::new(
                        &engine,
                        &oracle,
                        GoghOptions {
                            estimator: cfg.estimator.clone(),
                            optimizer: cfg.optimizer.clone(),
                            history_jobs: 24,
                            enable_refinement: true,
                            exploration_epsilon: 0.0,
                            seed,
                        },
                    )?;
                    driver.run(&mut sched)?
                }
            };
            reports.push(report);
        }
        agg.push((policy.to_string(), reports));
    }

    let mut table = SchedulerComparison::default();
    for (name, reports) in &agg {
        let n = reports.len() as f64;
        let mut mean = gogh::metrics::RunReport {
            scheduler: name.clone(),
            jobs_total: reports[0].jobs_total,
            ..Default::default()
        };
        for r in reports {
            mean.energy_joules += r.energy_joules / n;
            mean.total_energy_joules += r.total_energy_joules / n;
            mean.jobs_completed += r.jobs_completed / reports.len();
            mean.slo_deficit += r.slo_deficit / n;
            mean.slo_violations += r.slo_violations / reports.len();
            mean.migrations += r.migrations / reports.len();
            mean.mean_jct += r.mean_jct / n;
            mean.sim_seconds += r.sim_seconds / n;
            mean.mean_solve_ms += r.mean_solve_ms / n;
        }
        mean.estimation_mae = {
            let maes: Vec<f64> = reports.iter().filter_map(|r| r.estimation_mae).collect();
            (!maes.is_empty()).then(|| maes.iter().sum::<f64>() / maes.len() as f64)
        };
        table.push(mean);
    }
    println!("{}", table.table());
    println!("energy ratios vs random:");
    for (name, ratio) in table.energy_ratios() {
        println!("  {name:<12} {ratio:.3}x");
    }
    for r in &table.reports {
        if let Some(mae) = r.estimation_mae {
            println!("{} estimation MAE: {:.4}", r.scheduler, mae);
        }
        if r.mean_solve_ms > 0.0 {
            println!("{} mean ILP solve: {:.1} ms", r.scheduler, r.mean_solve_ms);
        }
    }
    Ok(())
}
