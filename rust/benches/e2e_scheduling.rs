//! End-to-end scheduling comparison (EXPERIMENTS.md §E2E): energy, SLO
//! satisfaction, completion time, migrations and per-event decision
//! latency for GOGH vs baselines on identical traces, plus GOGH's
//! online estimation MAE (the paper's "prediction errors as low as 5%"
//! headline) and the incremental-vs-full arrival-path solver cost.
//!
//!     cargo bench --bench e2e_scheduling

include!("bench_util.rs");

use gogh::baselines::{GreedyScheduler, OracleScheduler, RandomScheduler};
use gogh::cluster::ClusterSpec;
use gogh::config::ExperimentConfig;
use gogh::coordinator::{GoghOptions, GoghScheduler, SimDriver};
use gogh::metrics::SchedulerComparison;
use gogh::runtime::Engine;
use gogh::workload::{ThroughputOracle, Trace};

const SEEDS: [u64; 3] = [11, 12, 13];

fn main() -> gogh::Result<()> {
    let engine = Engine::load("artifacts")?;
    let mut cfg = ExperimentConfig::default();
    cfg.trace.n_jobs = 30;
    cfg.trace.mean_interarrival_s = 40.0;
    cfg.trace.mean_work_s = 800.0;

    println!("# E2E scheduler comparison, mean over seeds {SEEDS:?}");
    let mut agg: Vec<(String, Vec<gogh::metrics::RunReport>)> = vec![];
    for policy in ["random", "greedy", "gogh", "oracle-ilp"] {
        let mut reports = vec![];
        for &seed in &SEEDS {
            cfg.seed = seed;
            cfg.trace.seed = seed;
            let oracle = ThroughputOracle::new(seed);
            let trace = Trace::generate(&cfg.trace, &oracle);
            let mut driver = SimDriver::new(
                ClusterSpec::mix(&cfg.cluster.accel_mix),
                oracle.clone(),
                trace,
                cfg.noise_sigma,
                cfg.monitor_interval_s,
                seed,
            )?;
            let report = match policy {
                "random" => driver.run(&mut RandomScheduler::new(seed))?,
                "greedy" => driver.run(&mut GreedyScheduler::new())?,
                "oracle-ilp" => {
                    driver.run(&mut OracleScheduler::new(oracle, cfg.optimizer.clone()))?
                }
                _ => {
                    let mut sched = GoghScheduler::new(
                        &engine,
                        &oracle,
                        GoghOptions {
                            estimator: cfg.estimator.clone(),
                            optimizer: cfg.optimizer.clone(),
                            seed,
                            ..Default::default()
                        },
                    )?;
                    driver.run(&mut sched)?
                }
            };
            reports.push(report);
        }
        agg.push((policy.to_string(), reports));
    }

    let mut table = SchedulerComparison::default();
    for (name, reports) in &agg {
        let n = reports.len() as f64;
        let mut mean = gogh::metrics::RunReport {
            scheduler: name.clone(),
            jobs_total: reports[0].jobs_total,
            ..Default::default()
        };
        for r in reports {
            mean.energy_joules += r.energy_joules / n;
            mean.total_energy_joules += r.total_energy_joules / n;
            mean.jobs_completed += r.jobs_completed / reports.len();
            mean.slo_deficit += r.slo_deficit / n;
            mean.slo_violations += r.slo_violations / reports.len();
            mean.migrations += r.migrations / reports.len();
            mean.mean_jct += r.mean_jct / n;
            mean.sim_seconds += r.sim_seconds / n;
            mean.mean_solve_ms += r.mean_solve_ms / n;
            mean.mean_decision_ms += r.mean_decision_ms / n;
            mean.mean_queue_s += r.mean_queue_s / n;
        }
        mean.events = reports.iter().map(|r| r.events).sum::<usize>() / reports.len();
        mean.estimation_mae = {
            let maes: Vec<f64> = reports.iter().filter_map(|r| r.estimation_mae).collect();
            (!maes.is_empty()).then(|| maes.iter().sum::<f64>() / maes.len() as f64)
        };
        table.push(mean);
    }
    println!("{}", table.table());
    println!("energy ratios vs random:");
    for (name, ratio) in table.energy_ratios() {
        println!("  {name:<12} {ratio:.3}x");
    }
    println!("per-event decision latency:");
    for r in &table.reports {
        println!(
            "  {:<12} {:>8.3} ms/event over {} events",
            r.scheduler, r.mean_decision_ms, r.events
        );
    }
    for r in &table.reports {
        if let Some(mae) = r.estimation_mae {
            println!("{} estimation MAE: {:.4}", r.scheduler, mae);
        }
        if r.mean_solve_ms > 0.0 {
            println!("{} mean ILP solve: {:.1} ms", r.scheduler, r.mean_solve_ms);
        }
    }

    // ---- incremental arrival path vs full re-solve -------------------
    // At |J| ≥ 16 the bounded neighborhood ILP must explore no more
    // nodes per arrival solve than the full Problem-1 re-solve.
    println!("\n# GOGH incremental arrival path vs full re-solve (|J| = 16)");
    let mut icfg = ExperimentConfig::default();
    icfg.trace.n_jobs = 16;
    icfg.trace.mean_interarrival_s = 25.0;
    icfg.trace.mean_work_s = 1200.0;
    icfg.seed = 11;
    icfg.trace.seed = 11;
    let mut mean_nodes = [0.0f64; 2];
    for (slot, (label, full_every, neighborhood)) in
        [("incremental", 8usize, 4usize), ("full-resolve", 1, 0)].iter().enumerate()
    {
        let oracle = ThroughputOracle::new(icfg.seed);
        let trace = Trace::generate(&icfg.trace, &oracle);
        let mut driver = SimDriver::new(
            ClusterSpec::mix(&icfg.cluster.accel_mix),
            oracle.clone(),
            trace,
            icfg.noise_sigma,
            icfg.monitor_interval_s,
            icfg.seed,
        )?;
        let mut sched = GoghScheduler::new(
            &engine,
            &oracle,
            GoghOptions {
                estimator: icfg.estimator.clone(),
                optimizer: icfg.optimizer.clone(),
                full_resolve_every: *full_every,
                neighborhood: *neighborhood,
                seed: icfg.seed,
                ..Default::default()
            },
        )?;
        let report = driver.run(&mut sched)?;
        let stats = sched.solver_stats();
        mean_nodes[slot] = if *neighborhood > 0 {
            stats.mean_incremental_nodes()
        } else {
            stats.mean_full_nodes()
        };
        println!(
            "  {label:<13} {:>3} incremental solves ({:>7.1} nodes/solve), \
             {:>3} full solves ({:>7.1} nodes/solve), {:>7.3} ms/event",
            stats.incremental_solves,
            stats.mean_incremental_nodes(),
            stats.full_solves,
            stats.mean_full_nodes(),
            report.mean_decision_ms,
        );
    }
    assert!(
        mean_nodes[0] <= mean_nodes[1],
        "incremental path explored MORE nodes per solve than full re-solve: {} vs {}",
        mean_nodes[0],
        mean_nodes[1]
    );
    println!(
        "incremental/full nodes per solve: {:.1}/{:.1}",
        mean_nodes[0], mean_nodes[1]
    );
    Ok(())
}
