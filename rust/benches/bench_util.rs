// Shared helpers for the experiment benches (included via `include!`;
// replaces criterion in this offline build — see Cargo.toml).

use std::time::Instant;

/// Train an estimator on `samples` for `steps` Adam steps; returns the
/// final (loss, mae) pair.
#[allow(dead_code)]
pub fn train_estimator(
    est: &mut gogh::runtime::Estimator,
    samples: &[gogh::runtime::Sample],
    steps: usize,
    seed: u64,
) -> gogh::Result<(f32, f32)> {
    let batch = est.spec().train_batch;
    #[allow(unused_assignments)]
    let mut last = (f32::NAN, f32::NAN);
    let mut step = 0;
    let mut epoch = 0u64;
    'outer: loop {
        for (xs, ys) in gogh::runtime::dataset::batches(samples, batch, seed ^ epoch) {
            last = est.train_step(&xs, &ys)?;
            step += 1;
            if step >= steps {
                break 'outer;
            }
        }
        epoch += 1;
    }
    Ok(last)
}

/// Evaluate (mse, mae) of an estimator on samples.
#[allow(dead_code)]
pub fn eval_estimator(
    est: &mut gogh::runtime::Estimator,
    samples: &[gogh::runtime::Sample],
) -> gogh::Result<(f32, f32)> {
    let xs: Vec<Vec<f32>> = samples.iter().map(|s| s.x.clone()).collect();
    let ys: Vec<[f32; 2]> = samples.iter().map(|s| s.y).collect();
    est.evaluate(&xs, &ys)
}

/// Median wall time of `f` over `iters` runs (warmup 2), in seconds.
#[allow(dead_code)]
pub fn median_time<F: FnMut()>(mut f: F, iters: usize) -> f64 {
    for _ in 0..2 {
        f();
    }
    let mut times: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

#[allow(dead_code)]
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}
