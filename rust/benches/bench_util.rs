// Shared helpers for the experiment benches (included via `include!`;
// replaces criterion in this offline build — see Cargo.toml).

use std::time::Instant;

/// Train an estimator backend (PJRT or native) on `samples` for
/// `steps` Adam steps; returns the final (loss, mae) pair.
#[allow(dead_code)]
pub fn train_estimator(
    est: &mut dyn gogh::runtime::Backend,
    samples: &[gogh::runtime::Sample],
    steps: usize,
    seed: u64,
) -> gogh::Result<(f32, f32)> {
    let batch = est.train_batch();
    #[allow(unused_assignments)]
    let mut last = (f32::NAN, f32::NAN);
    let mut step = 0;
    let mut epoch = 0u64;
    'outer: loop {
        for (xs, ys) in gogh::runtime::dataset::batches(samples, batch, seed ^ epoch) {
            last = est.train_step(&xs, &ys)?;
            step += 1;
            if step >= steps {
                break 'outer;
            }
        }
        epoch += 1;
    }
    Ok(last)
}

/// Train + evaluate one estimator backend over a split and print one
/// row of the fig2a/fig2b table (arch, train/val/test MAE, final train
/// loss, per-step time).
#[allow(dead_code)]
pub fn bench_row(
    label: &str,
    est: &mut dyn gogh::runtime::Backend,
    split: &gogh::runtime::Split,
    steps: usize,
    seed: u64,
) -> gogh::Result<()> {
    let t0 = Instant::now();
    let (final_loss, _) = train_estimator(est, &split.train, steps, seed)?;
    let step_time = t0.elapsed().as_secs_f64() / steps as f64;
    let (_, train_mae) = eval_estimator(est, &split.train)?;
    let (_, val_mae) = eval_estimator(est, &split.val)?;
    let (_, test_mae) = eval_estimator(est, &split.test)?;
    println!(
        "{:<14} {:>11.4} {:>11.4} {:>11.4} {:>11.5} {:>12}",
        label,
        train_mae,
        val_mae,
        test_mae,
        final_loss,
        fmt_time(step_time)
    );
    Ok(())
}

/// Evaluate (mse, mae) of an estimator backend on samples.
#[allow(dead_code)]
pub fn eval_estimator(
    est: &mut dyn gogh::runtime::Backend,
    samples: &[gogh::runtime::Sample],
) -> gogh::Result<(f32, f32)> {
    let xs: Vec<Vec<f32>> = samples.iter().map(|s| s.x.clone()).collect();
    let ys: Vec<[f32; 2]> = samples.iter().map(|s| s.y).collect();
    est.evaluate(&xs, &ys)
}

/// Median wall time of `f` over `iters` runs (warmup 2), in seconds.
#[allow(dead_code)]
pub fn median_time<F: FnMut()>(mut f: F, iters: usize) -> f64 {
    for _ in 0..2 {
        f();
    }
    let mut times: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

#[allow(dead_code)]
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}
