//! Figure 2b — MAE of the refinement network P2 across architectures
//! on train / validation / test.
//!
//!     cargo bench --bench fig2b_p2
//!
//! Paper shape: FF is the most consistent and generalizable in the
//! refinement stage; the Transformer shows the highest variability.

include!("bench_util.rs");

use gogh::runtime::{DatasetBuilder, Engine, Estimator};
use gogh::workload::ThroughputOracle;

const SEED: u64 = 29;
const N_TRAIN: usize = 6000;
const N_EVAL: usize = 1500;
const STEPS: usize = 400;

fn main() -> gogh::Result<()> {
    let engine = Engine::load("artifacts")?;
    let oracle = ThroughputOracle::new(SEED);
    let builder = DatasetBuilder::new(&oracle, SEED);
    let split = builder.build_split("p2", N_TRAIN, N_EVAL);
    let (ntr, nva, nte) = split.sizes();
    println!("# Figure 2b — P2 estimation-refinement MAE");
    println!("# dataset: {ntr} train / {nva} val / {nte} test samples, {STEPS} Adam steps");
    println!(
        "{:<14} {:>11} {:>11} {:>11} {:>11} {:>12}",
        "arch", "train_mae", "val_mae", "test_mae", "train_loss", "step_time"
    );
    for arch in ["ff", "rnn", "transformer"] {
        let mut est = Estimator::new(&engine, &format!("p2_{arch}"))?;
        let t0 = std::time::Instant::now();
        let (final_loss, _) = train_estimator(&mut est, &split.train, STEPS, SEED)?;
        let step_time = t0.elapsed().as_secs_f64() / STEPS as f64;
        let (_, train_mae) = eval_estimator(&mut est, &split.train)?;
        let (_, val_mae) = eval_estimator(&mut est, &split.val)?;
        let (_, test_mae) = eval_estimator(&mut est, &split.test)?;
        println!(
            "{:<14} {:>11.4} {:>11.4} {:>11.4} {:>11.5} {:>12}",
            arch,
            train_mae,
            val_mae,
            test_mae,
            final_loss,
            fmt_time(step_time)
        );
    }
    Ok(())
}
