//! Figure 2a — MAE of the initial-estimation network P1 across
//! architectures (FF / RNN / Transformer) on train / validation / test.
//!
//!     cargo bench --bench fig2a_p1
//!
//! Paper shape: RNN best on train+val; Transformer generalizes best on
//! the unseen test configs. Absolute values differ (synthetic dataset).
//!
//! Without PJRT artifacts the bench does not skip: it runs the same
//! dataset through the native pure-Rust backend (one `native-mlp` row),
//! so the P1 estimation task stays exercised in every environment.

include!("bench_util.rs");

use gogh::runtime::{DatasetBuilder, Engine, Estimator, NativeBackend};
use gogh::workload::ThroughputOracle;

const SEED: u64 = 29;
const N_TRAIN: usize = 6000;
const N_EVAL: usize = 1500;
const STEPS: usize = 400;

fn main() -> gogh::Result<()> {
    let oracle = ThroughputOracle::new(SEED);
    let builder = DatasetBuilder::new(&oracle, SEED);
    let split = builder.build_split("p1", N_TRAIN, N_EVAL);
    let (ntr, nva, nte) = split.sizes();
    println!("# Figure 2a — P1 initial estimation MAE");
    println!("# dataset: {ntr} train / {nva} val / {nte} test samples, {STEPS} Adam steps");
    println!(
        "{:<14} {:>11} {:>11} {:>11} {:>11} {:>12}",
        "arch", "train_mae", "val_mae", "test_mae", "train_loss", "step_time"
    );
    match Engine::load("artifacts") {
        Ok(engine) => {
            for arch in ["ff", "rnn", "transformer"] {
                let mut est = Estimator::new(&engine, &format!("p1_{arch}"))?;
                bench_row(arch, &mut est, &split, STEPS, SEED)?;
            }
        }
        Err(err) => {
            println!("# (no PJRT artifacts: {err}; running the native pure-Rust backend)");
            let mut est = NativeBackend::p1(SEED);
            bench_row("native-mlp", &mut est, &split, STEPS, SEED)?;
        }
    }
    Ok(())
}
