//! Optimizer (Problem 1) solve-time scaling — the §2.4 discussion: the
//! paper uses a general-purpose solver and defers faster algorithms to
//! future work; this bench quantifies where the in-tree B&B solver
//! stands as |J| and the cluster grow, and how much the greedy warm
//! start (baselines::greedy) and workspace-reuse simplex buy:
//!
//! * `nodes_w` / `nodes_c` — branch-and-bound nodes explored with the
//!   warm-started vs cold-started search (same instance, same budgets);
//! * `piv/node` — mean simplex pivots per explored node (the per-node
//!   cost that workspace reuse keeps allocation-free);
//! * `ms_w` / `ms_c` — wall-clock per solve.
//!
//!     cargo bench --bench ilp_scaling

include!("bench_util.rs");

use std::collections::BTreeMap;

use gogh::ilp::branch_bound::BnbConfig;
use gogh::ilp::problem1::{
    build_problem1, solve_problem1, solve_problem1_with_basis, ColumnBasis, Problem1Input,
};
use gogh::workload::{AccelType, Combo, JobId, JobSpec, ThroughputOracle, ACCEL_TYPES, FAMILIES};

fn mk_jobs(n: u32, oracle: &ThroughputOracle) -> Vec<JobSpec> {
    (0..n)
        .map(|i| {
            let f = FAMILIES[i as usize % FAMILIES.len()];
            let b = f.batch_sizes()[i as usize % f.batch_sizes().len()];
            let mut j = JobSpec {
                id: JobId(i),
                family: f,
                batch_size: b,
                replication: 1,
                min_throughput: 0.0,
                distributability: 2,
                work: 100.0,
                priority: Default::default(),
                elastic: false,
                inference: None,
            };
            j.min_throughput = 0.35 * oracle.solo(&j, AccelType::P100);
            j
        })
        .collect()
}

fn main() {
    let oracle = ThroughputOracle::new(41);
    println!("# Problem 1 (GPU-allocation ILP) solve-time scaling, warm vs cold start");
    println!(
        "{:>5} {:>10} {:>7} {:>8} {:>8} {:>8} {:>9} {:>10} {:>10} {:>8} {:>10}",
        "jobs", "instances", "vars", "cons", "nodes_w", "nodes_c", "piv/node", "ms_w", "ms_c", "gap%", "status"
    );
    let mut total_warm_nodes = 0usize;
    let mut total_cold_nodes = 0usize;
    for &per_type in &[1u32, 2, 4] {
        for &n_jobs in &[4u32, 8, 12, 16, 24] {
            let jobs = mk_jobs(n_jobs, &oracle);
            let jobs_c = jobs.clone();
            let oracle_c = oracle.clone();
            let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
                let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
                let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
                oracle_c.throughput(spec, c, a, &lookup)
            };
            let cap = |a: AccelType| a.base_speed() / AccelType::V100.base_speed();
            let counts: BTreeMap<AccelType, u32> =
                ACCEL_TYPES.iter().map(|&a| (a, per_type)).collect();
            let input = Problem1Input {
                jobs: &jobs,
                accel_counts: &counts,
                throughput: &thr,
                solo_capability: &cap,
                max_pairs_per_job: 3,
                slack_penalty: Some(2000.0),
                throughput_bonus: 300.0,
                now_s: 0.0,
                power: Default::default(),
            };
            let warm_cfg = BnbConfig {
                max_nodes: 8_000,
                time_limit_s: 10.0,
                ..Default::default()
            };
            let cold_cfg = BnbConfig {
                auto_warm_start: false,
                ..warm_cfg.clone()
            };
            let (model, _, _) = build_problem1(&input, &warm_cfg);
            let t0 = std::time::Instant::now();
            let warm = solve_problem1(&input, &warm_cfg);
            let ms_w = t0.elapsed().as_secs_f64() * 1e3;
            let t1 = std::time::Instant::now();
            let cold = solve_problem1(&input, &cold_cfg);
            let ms_c = t1.elapsed().as_secs_f64() * 1e3;
            total_warm_nodes += warm.nodes;
            total_cold_nodes += cold.nodes;
            let piv_per_node = warm.lp_pivots as f64 / warm.nodes.max(1) as f64;
            println!(
                "{:>5} {:>10} {:>7} {:>8} {:>8} {:>8} {:>9.1} {:>10.1} {:>10.1} {:>8.2} {:>10?}",
                n_jobs,
                per_type * 6,
                model.n_vars(),
                model.n_constraints(),
                warm.nodes,
                cold.nodes,
                piv_per_node,
                ms_w,
                ms_c,
                warm.gap * 100.0,
                warm.status
            );
        }
    }
    println!(
        "# total nodes explored: warm {total_warm_nodes} vs cold {total_cold_nodes} \
         ({:.1}% saved by the greedy incumbent)",
        100.0 * (1.0 - total_warm_nodes as f64 / total_cold_nodes.max(1) as f64)
    );

    // --- basis reuse across arrivals ---------------------------------
    // The sharded decision path chains each local solve off the basis
    // its pool exported last arrival. Replay that shape: a growing job
    // set, each step solved (a) chained off the previous step's basis
    // and (b) cold, comparing cumulative simplex pivots.
    println!("\n# arrival chaining: simplex basis reuse across related solves");
    println!("{:>5} {:>10} {:>10} {:>10} {:>10}", "jobs", "piv_chain", "piv_cold", "ms_chain", "ms_cold");
    let mut chained_pivots = 0usize;
    let mut cold_pivots = 0usize;
    let mut basis = ColumnBasis::new();
    for n_jobs in 6u32..=16 {
        let jobs = mk_jobs(n_jobs, &oracle);
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / AccelType::V100.base_speed();
        let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 2)).collect();
        let input = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &cap,
            max_pairs_per_job: 2,
            slack_penalty: Some(2000.0),
            throughput_bonus: 300.0,
            now_s: 0.0,
            power: Default::default(),
        };
        let cfg = BnbConfig {
            max_nodes: 8_000,
            time_limit_s: 10.0,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let chained = solve_problem1_with_basis(&input, &cfg, &basis);
        let ms_chain = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = std::time::Instant::now();
        let cold = solve_problem1(&input, &cfg);
        let ms_cold = t1.elapsed().as_secs_f64() * 1e3;
        chained_pivots += chained.lp_pivots;
        cold_pivots += cold.lp_pivots;
        if let Some(b) = chained.basis {
            basis = b;
        }
        println!(
            "{:>5} {:>10} {:>10} {:>10.1} {:>10.1}",
            n_jobs, chained.lp_pivots, cold.lp_pivots, ms_chain, ms_cold
        );
    }
    println!(
        "# cumulative LP pivots: chained {chained_pivots} vs cold {cold_pivots} \
         ({:.1}% saved by basis reuse)",
        100.0 * (1.0 - chained_pivots as f64 / cold_pivots.max(1) as f64)
    );
    assert!(
        chained_pivots < cold_pivots,
        "basis chaining must save simplex pivots: chained {chained_pivots} vs cold {cold_pivots}"
    );
}
