//! Hot-path micro-benchmarks (§Perf): the latencies that sit on GOGH's
//! decision path — catalog ops, similarity search, feature encoding,
//! LP pivoting, PJRT predict/train-step.
//!
//!     cargo bench --bench hotpath

include!("bench_util.rs");

use std::collections::BTreeMap;

use gogh::catalog::{Catalog, EstimateKey, SimilarityIndex};
use gogh::ilp::branch_bound::BnbConfig;
use gogh::ilp::model::{Model, ObjSense, Sense, VarKind};
use gogh::ilp::problem1::{solve_problem1, Problem1Input};
use gogh::ilp::simplex::{solve_lp, SimplexWorkspace};
use gogh::runtime::{Engine, Estimator};
use gogh::util::Rng;
use gogh::workload::encoding::{p1_row, psi};
use gogh::workload::{
    AccelType, Combo, JobId, JobSpec, ModelFamily, ThroughputOracle, ACCEL_TYPES, FAMILIES,
};

fn bench<F: FnMut()>(name: &str, per_call: usize, iters: usize, f: F) {
    let t = median_time(f, iters);
    println!("{:<34} {:>12} / call", name, fmt_time(t / per_call as f64));
}

fn main() -> gogh::Result<()> {
    println!("# GOGH hot-path micro-benchmarks (median wall time)");

    // ---- RNG
    let mut rng = Rng::seed_from_u64(1);
    bench("rng.f64 x1000", 1000, 50, || {
        let mut acc = 0.0;
        for _ in 0..1000 {
            acc += rng.f64();
        }
        std::hint::black_box(acc);
    });

    // ---- feature encoding
    let pa = psi(ModelFamily::ResNet50, 64, 1);
    let pb = psi(ModelFamily::LanguageModel, 10, 1);
    bench("p1_row encode x1000", 1000, 50, || {
        for _ in 0..1000 {
            std::hint::black_box(p1_row(&pa, &pb, AccelType::V100, 0.5, 0.25, &pa));
        }
    });

    // ---- catalog ops
    let mut catalog = Catalog::new();
    for i in 0..2000u32 {
        let f = gogh::workload::FAMILIES[i as usize % 5];
        catalog.register_job(JobId(i), psi(f, f.batch_sizes()[0], 1));
        catalog.record_measurement(
            EstimateKey {
                accel: AccelType::K80,
                job: JobId(i),
                combo: Combo::Solo(JobId(i)),
            },
            0.5,
        );
    }
    let key = EstimateKey {
        accel: AccelType::K80,
        job: JobId(500),
        combo: Combo::Solo(JobId(500)),
    };
    bench("catalog.value x1000", 1000, 50, || {
        for _ in 0..1000 {
            std::hint::black_box(catalog.value(&key));
        }
    });
    bench("similarity over 2000 jobs", 1, 20, || {
        let idx = SimilarityIndex::new(&catalog);
        std::hint::black_box(idx.most_similar(&pa, &[], false));
    });

    // ---- simplex on a mid-size LP (60 vars, 40 rows)
    let mut model = Model::new(ObjSense::Minimize);
    let mut lp_rng = Rng::seed_from_u64(2);
    let vars: Vec<_> = (0..60)
        .map(|i| {
            let obj = lp_rng.range_f64(1.0, 5.0);
            model.add_var(format!("x{i}"), 0.0, 10.0, VarKind::Continuous, obj)
        })
        .collect();
    for r in 0..40 {
        let mut terms: Vec<_> = vec![];
        for &v in &vars {
            if lp_rng.bool(0.3) {
                terms.push((v, lp_rng.range_f64(0.1, 2.0)));
            }
        }
        if !terms.is_empty() {
            model.add_constraint(format!("c{r}"), terms, Sense::Ge, lp_rng.range_f64(1.0, 8.0));
        }
    }
    bench("simplex 60x40 LP (fresh alloc)", 1, 20, || {
        std::hint::black_box(solve_lp(&model, None));
    });
    let mut ws = SimplexWorkspace::new();
    ws.solve(&model, None); // prime the buffers
    bench("simplex 60x40 LP (reused ws)", 1, 20, || {
        std::hint::black_box(ws.solve(&model, None));
    });

    // ---- Problem 1 B&B on the decision path (|J| = 8, 12 instances):
    // warm = greedy incumbent from baselines::greedy, cold = no incumbent.
    let oracle = ThroughputOracle::new(41);
    let jobs: Vec<JobSpec> = (0..8u32)
        .map(|i| {
            let f = FAMILIES[i as usize % FAMILIES.len()];
            let b = f.batch_sizes()[i as usize % f.batch_sizes().len()];
            let mut j = JobSpec {
                id: JobId(i),
                family: f,
                batch_size: b,
                replication: 1,
                min_throughput: 0.0,
                distributability: 2,
                work: 100.0,
                priority: Default::default(),
                elastic: false,
                inference: None,
            };
            j.min_throughput = 0.35 * oracle.solo(&j, AccelType::P100);
            j
        })
        .collect();
    let jobs_c = jobs.clone();
    let oracle_c = oracle.clone();
    let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
        let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
        let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
        oracle_c.throughput(spec, c, a, &lookup)
    };
    let cap = |a: AccelType| a.base_speed() / AccelType::V100.base_speed();
    let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 2)).collect();
    let input = Problem1Input {
        jobs: &jobs,
        accel_counts: &counts,
        throughput: &thr,
        solo_capability: &cap,
        max_pairs_per_job: 3,
        slack_penalty: Some(2000.0),
        throughput_bonus: 300.0,
        now_s: 0.0,
        power: Default::default(),
    };
    let warm_cfg = BnbConfig::default();
    let cold_cfg = BnbConfig {
        auto_warm_start: false,
        ..Default::default()
    };
    bench("problem1 B&B |J|=8 warm", 1, 10, || {
        std::hint::black_box(solve_problem1(&input, &warm_cfg));
    });
    bench("problem1 B&B |J|=8 cold", 1, 10, || {
        std::hint::black_box(solve_problem1(&input, &cold_cfg));
    });
    let warm = solve_problem1(&input, &warm_cfg);
    let cold = solve_problem1(&input, &cold_cfg);
    println!(
        "problem1 nodes: warm {} ({:.1} pivots/node) vs cold {} ({:.1} pivots/node)",
        warm.nodes,
        warm.lp_pivots as f64 / warm.nodes.max(1) as f64,
        cold.nodes,
        cold.lp_pivots as f64 / cold.nodes.max(1) as f64
    );

    // ---- PJRT paths (skip when artifacts absent)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let engine = Engine::load("artifacts")?;
        let mut p1 = Estimator::new(&engine, "p1_rnn")?;
        let rows: Vec<Vec<f32>> = (0..256).map(|_| vec![0.3f32; 32]).collect();
        bench("p1_rnn predict batch=256", 1, 10, || {
            std::hint::black_box(p1.predict(&rows).unwrap());
        });
        let mut p2 = Estimator::new(&engine, "p2_ff")?;
        let rows2: Vec<Vec<f32>> = (0..256).map(|_| vec![0.3f32; 40]).collect();
        bench("p2_ff predict batch=256", 1, 10, || {
            std::hint::black_box(p2.predict(&rows2).unwrap());
        });
        let xs: Vec<Vec<f32>> = (0..256).map(|_| vec![0.2f32; 40]).collect();
        let ys: Vec<[f32; 2]> = (0..256).map(|_| [0.4, 0.5]).collect();
        bench("p2_ff train_step batch=256", 1, 10, || {
            std::hint::black_box(p2.train_step(&xs, &ys).unwrap());
        });
        let xs1: Vec<Vec<f32>> = (0..256).map(|_| vec![0.2f32; 32]).collect();
        bench("p1_rnn train_step batch=256", 1, 10, || {
            std::hint::black_box(p1.train_step(&xs1, &ys).unwrap());
        });
    } else {
        println!("(artifacts missing — PJRT benches skipped)");
    }
    Ok(())
}
