//! Hot-path micro-benchmarks (§Perf): the latencies that sit on GOGH's
//! decision path — catalog ops, similarity search, feature encoding,
//! LP pivoting, PJRT predict/train-step.
//!
//!     cargo bench --bench hotpath

include!("bench_util.rs");

use gogh::catalog::{Catalog, EstimateKey, SimilarityIndex};
use gogh::ilp::model::{Model, ObjSense, Sense, VarKind};
use gogh::ilp::simplex::solve_lp;
use gogh::runtime::{Engine, Estimator};
use gogh::util::Rng;
use gogh::workload::encoding::{p1_row, psi};
use gogh::workload::{AccelType, Combo, JobId, ModelFamily};

fn bench<F: FnMut()>(name: &str, per_call: usize, iters: usize, f: F) {
    let t = median_time(f, iters);
    println!("{:<34} {:>12} / call", name, fmt_time(t / per_call as f64));
}

fn main() -> gogh::Result<()> {
    println!("# GOGH hot-path micro-benchmarks (median wall time)");

    // ---- RNG
    let mut rng = Rng::seed_from_u64(1);
    bench("rng.f64 x1000", 1000, 50, || {
        let mut acc = 0.0;
        for _ in 0..1000 {
            acc += rng.f64();
        }
        std::hint::black_box(acc);
    });

    // ---- feature encoding
    let pa = psi(ModelFamily::ResNet50, 64, 1);
    let pb = psi(ModelFamily::LanguageModel, 10, 1);
    bench("p1_row encode x1000", 1000, 50, || {
        for _ in 0..1000 {
            std::hint::black_box(p1_row(&pa, &pb, AccelType::V100, 0.5, 0.25, &pa));
        }
    });

    // ---- catalog ops
    let mut catalog = Catalog::new();
    for i in 0..2000u32 {
        let f = gogh::workload::FAMILIES[i as usize % 5];
        catalog.register_job(JobId(i), psi(f, f.batch_sizes()[0], 1));
        catalog.record_measurement(
            EstimateKey {
                accel: AccelType::K80,
                job: JobId(i),
                combo: Combo::Solo(JobId(i)),
            },
            0.5,
        );
    }
    let key = EstimateKey {
        accel: AccelType::K80,
        job: JobId(500),
        combo: Combo::Solo(JobId(500)),
    };
    bench("catalog.value x1000", 1000, 50, || {
        for _ in 0..1000 {
            std::hint::black_box(catalog.value(&key));
        }
    });
    bench("similarity over 2000 jobs", 1, 20, || {
        let idx = SimilarityIndex::new(&catalog);
        std::hint::black_box(idx.most_similar(&pa, &[], false));
    });

    // ---- simplex on a mid-size LP (60 vars, 40 rows)
    let mut model = Model::new(ObjSense::Minimize);
    let mut lp_rng = Rng::seed_from_u64(2);
    let vars: Vec<_> = (0..60)
        .map(|i| model.add_var(format!("x{i}"), 0.0, 10.0, VarKind::Continuous, lp_rng.range_f64(1.0, 5.0)))
        .collect();
    for r in 0..40 {
        let mut terms: Vec<_> = vec![];
        for &v in &vars {
            if lp_rng.bool(0.3) {
                terms.push((v, lp_rng.range_f64(0.1, 2.0)));
            }
        }
        if !terms.is_empty() {
            model.add_constraint(format!("c{r}"), terms, Sense::Ge, lp_rng.range_f64(1.0, 8.0));
        }
    }
    bench("simplex 60x40 LP", 1, 20, || {
        std::hint::black_box(solve_lp(&model, None));
    });

    // ---- PJRT paths (skip when artifacts absent)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let engine = Engine::load("artifacts")?;
        let mut p1 = Estimator::new(&engine, "p1_rnn")?;
        let rows: Vec<Vec<f32>> = (0..256).map(|_| vec![0.3f32; 32]).collect();
        bench("p1_rnn predict batch=256", 1, 10, || {
            std::hint::black_box(p1.predict(&rows).unwrap());
        });
        let mut p2 = Estimator::new(&engine, "p2_ff")?;
        let rows2: Vec<Vec<f32>> = (0..256).map(|_| vec![0.3f32; 40]).collect();
        bench("p2_ff predict batch=256", 1, 10, || {
            std::hint::black_box(p2.predict(&rows2).unwrap());
        });
        let xs: Vec<Vec<f32>> = (0..256).map(|_| vec![0.2f32; 40]).collect();
        let ys: Vec<[f32; 2]> = (0..256).map(|_| [0.4, 0.5]).collect();
        bench("p2_ff train_step batch=256", 1, 10, || {
            std::hint::black_box(p2.train_step(&xs, &ys).unwrap());
        });
        let xs1: Vec<Vec<f32>> = (0..256).map(|_| vec![0.2f32; 32]).collect();
        bench("p1_rnn train_step batch=256", 1, 10, || {
            std::hint::black_box(p1.train_step(&xs1, &ys).unwrap());
        });
    } else {
        println!("(artifacts missing — PJRT benches skipped)");
    }
    Ok(())
}
