//! End-to-end tests for `goghd`: protocol smoke over a Unix socket,
//! and crash-safety (SIGKILL + restart restores jobs, placements, and
//! the learned catalog from the snapshot file).
//!
//! Both tests spawn the real binary (`CARGO_BIN_EXE_goghd`) and speak
//! the newline-delimited JSON protocol over raw sockets, exactly as an
//! external client would.

use std::io::{BufRead as _, BufReader, Write as _};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use gogh::util::Json;

/// Kills the daemon on drop so a failing assert can't leak a process.
struct Daemon(Child);

impl Drop for Daemon {
    fn drop(&mut self) {
        self.0.kill().ok();
        self.0.wait().ok();
    }
}

fn spawn_daemon(args: &[&str]) -> Daemon {
    Daemon(
        Command::new(env!("CARGO_BIN_EXE_goghd"))
            .args(args)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning goghd"),
    )
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("goghd_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Wait (max 30 s) until `f` returns Some.
fn poll<T>(what: &str, mut f: impl FnMut() -> Option<T>) -> T {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Some(v) = f() {
            return v;
        }
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// One request/response exchange over a fresh Unix-socket connection.
fn request_unix(sock: &Path, line: &str) -> Json {
    let mut s = std::os::unix::net::UnixStream::connect(sock).expect("connect");
    writeln!(s, "{line}").unwrap();
    let mut resp = String::new();
    BufReader::new(s).read_line(&mut resp).unwrap();
    Json::parse(resp.trim()).expect("response is JSON")
}

/// One request/response exchange over a fresh TCP connection.
fn request_tcp(addr: &str, line: &str) -> Json {
    let mut s = std::net::TcpStream::connect(addr).expect("connect");
    writeln!(s, "{line}").unwrap();
    let mut resp = String::new();
    BufReader::new(s).read_line(&mut resp).unwrap();
    Json::parse(resp.trim()).expect("response is JSON")
}

fn is_ok(v: &Json) -> bool {
    v.get("ok").and_then(Json::as_bool) == Some(true)
}

fn error_code(v: &Json) -> &str {
    v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str).unwrap_or("")
}

#[test]
fn daemon_smoke_submit_status_cancel_drain() {
    let dir = fresh_dir("smoke");
    let sock = dir.join("goghd.sock");
    // time-scale 60: one 30-sim-second monitor interval ≈ 0.5 wall s
    let daemon = spawn_daemon(&[
        "--backend",
        "native",
        "--socket",
        sock.to_str().unwrap(),
        "--time-scale",
        "60",
    ]);
    poll("socket to appear", || sock.exists().then_some(()));

    // submit two training jobs over the wire (work is large enough that
    // neither can finish before the cancels below, even at 60x)
    let r = request_unix(&sock, r#"{"cmd":"submit","job":{"family":"resnet50","work":1e6}}"#);
    assert!(is_ok(&r), "{r}");
    assert_eq!(r.get("id").and_then(Json::as_u64), Some(0));
    let r = request_unix(&sock, r#"{"cmd":"submit","job":{"family":"lm","work":1e6}}"#);
    assert!(is_ok(&r), "{r}");
    assert_eq!(r.get("id").and_then(Json::as_u64), Some(1));

    // queue lists both
    let q = poll("both jobs active", || {
        let q = request_unix(&sock, r#"{"cmd":"queue"}"#);
        (q.get("jobs").and_then(Json::as_array).map(<[Json]>::len) == Some(2)).then_some(q)
    });
    assert!(is_ok(&q), "{q}");

    // the GOGH policy places them (visible via status)
    let s = poll("placements in status", || {
        let s = request_unix(&sock, r#"{"cmd":"status"}"#);
        (!s.get("placements").and_then(Json::as_array).unwrap_or(&[]).is_empty()).then_some(s)
    });
    let catalog_records =
        s.get("catalog").and_then(|c| c.get("records")).and_then(Json::as_u64).unwrap();
    assert!(catalog_records > 0, "learned estimates should exist: {s}");

    // protocol errors use the envelope
    let r = request_unix(&sock, r#"{"cmd":"cancel","job":99}"#);
    assert!(!is_ok(&r));
    assert_eq!(error_code(&r), "unknown_job");
    let r = request_unix(&sock, r#"{"cmd":"warp"}"#);
    assert_eq!(error_code(&r), "unknown_cmd");
    let r = request_unix(&sock, "{broken");
    assert_eq!(error_code(&r), "bad_request");
    let r = request_unix(&sock, r#"{"v":99,"cmd":"queue"}"#);
    assert_eq!(error_code(&r), "unsupported_version");

    // cancel one, drain, and the daemon must refuse new work
    let r = request_unix(&sock, r#"{"cmd":"cancel","job":0}"#);
    assert!(is_ok(&r), "{r}");
    let r = request_unix(&sock, r#"{"cmd":"drain"}"#);
    assert!(is_ok(&r), "{r}");
    let r = request_unix(&sock, r#"{"cmd":"submit","job":{"family":"lm","work":60}}"#);
    assert_eq!(error_code(&r), "draining");

    // cancel the last job → the daemon drains and exits cleanly
    let r = request_unix(&sock, r#"{"cmd":"cancel","job":1}"#);
    assert!(is_ok(&r), "{r}");
    let mut daemon = daemon;
    let status = poll("clean exit after drain", || daemon.0.try_wait().unwrap());
    assert!(status.success(), "goghd should exit 0 after draining, got {status}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn daemon_kill_and_resume_restores_state() {
    let dir = fresh_dir("resume");
    let state = dir.join("state.json");
    let port_file = dir.join("port");
    let flags = |pf: &Path| {
        vec![
            "--backend".to_string(),
            "native".to_string(),
            "--addr".to_string(),
            "127.0.0.1:0".to_string(),
            "--port-file".to_string(),
            pf.to_str().unwrap().to_string(),
            "--state".to_string(),
            state.to_str().unwrap().to_string(),
            "--snapshot-every".to_string(),
            "0.2".to_string(),
        ]
    };
    let args: Vec<String> = flags(&port_file);
    let daemon = Daemon(
        Command::new(env!("CARGO_BIN_EXE_goghd"))
            .args(&args)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .unwrap(),
    );
    let addr = poll("port file", || {
        std::fs::read_to_string(&port_file).ok().map(|p| format!("127.0.0.1:{}", p.trim()))
    });

    // two effectively-endless jobs so state is nontrivial at kill time
    let r = request_tcp(&addr, r#"{"cmd":"submit","job":{"family":"resnet18","work":1e9}}"#);
    assert!(is_ok(&r), "{r}");
    let r = request_tcp(&addr, r#"{"cmd":"submit","job":{"family":"transformer","work":1e9}}"#);
    assert!(is_ok(&r), "{r}");

    // wait until a snapshot on disk shows both jobs placed
    let snap = poll("snapshot with both jobs placed", || {
        let text = std::fs::read_to_string(&state).ok()?;
        let v = Json::parse(&text).ok()?;
        let jobs = v.get("jobs")?.as_array()?.len();
        let placements = v.get("placements")?.as_array()?.len();
        (jobs == 2 && placements > 0).then_some(v)
    });
    let snap_records = snap
        .get("catalog")
        .and_then(|c| c.get("records"))
        .and_then(Json::as_array)
        .map(<[Json]>::len)
        .unwrap();
    assert!(snap_records > 0, "snapshot should carry learned estimates");

    // SIGKILL: no clean-shutdown path runs
    drop(daemon);

    // restart on a new ephemeral port, same state file
    let port_file2 = dir.join("port2");
    std::fs::remove_file(&port_file).ok();
    let args2: Vec<String> = flags(&port_file2);
    let _daemon2 = Daemon(
        Command::new(env!("CARGO_BIN_EXE_goghd"))
            .args(&args2)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .unwrap(),
    );
    let addr2 = poll("port file after restart", || {
        std::fs::read_to_string(&port_file2).ok().map(|p| format!("127.0.0.1:{}", p.trim()))
    });

    let status = request_tcp(&addr2, r#"{"cmd":"status"}"#);
    assert!(is_ok(&status), "{status}");

    // same active jobs and catalog record count as the snapshot file
    let active = status.get("jobs").and_then(|j| j.get("active")).and_then(Json::as_u64).unwrap();
    assert_eq!(active, 2, "both jobs survive the restart: {status}");
    let restored_records = status
        .get("catalog")
        .and_then(|c| c.get("records"))
        .and_then(Json::as_u64)
        .unwrap() as usize;
    assert_eq!(restored_records, snap_records, "catalog restored verbatim");

    // same placements: compare (accel, jobs) pairs to the snapshot
    let mut snap_placements: Vec<String> = snap
        .get("placements")
        .and_then(Json::as_array)
        .unwrap()
        .iter()
        .map(|p| {
            let a = p.get("accel").unwrap();
            let server = a.req_f64("server").unwrap() as u64;
            let ty = a.req_str("type").unwrap();
            format!("s{server}/{ty} {}", p.get("jobs").unwrap())
        })
        .collect();
    snap_placements.sort();
    let mut restored_placements: Vec<String> = status
        .get("placements")
        .and_then(Json::as_array)
        .unwrap()
        .iter()
        .map(|p| format!("{} {}", p.req_str("accel").unwrap(), p.get("jobs").unwrap()))
        .collect();
    restored_placements.sort();
    assert_eq!(restored_placements, snap_placements);

    // a restarted daemon keeps allocating fresh ids (no collisions)
    let r = request_tcp(&addr2, r#"{"cmd":"submit","job":{"family":"lm","work":60}}"#);
    assert!(is_ok(&r), "{r}");
    assert_eq!(r.get("id").and_then(Json::as_u64), Some(2));

    std::fs::remove_dir_all(&dir).ok();
}

/// A corrupt snapshot file must refuse (non-`--fresh`) startup with a
/// named error on stderr — never panic, never silently start empty —
/// while `--fresh` explicitly discards it and starts clean.
#[test]
fn garbage_snapshot_fails_startup_gracefully() {
    let dir = fresh_dir("garbage_snap");
    let state = dir.join("state.json");
    std::fs::write(&state, "{not json at all").unwrap();
    let state_s = state.to_str().unwrap();
    let sock = dir.join("d.sock");
    let sock_s = sock.to_str().unwrap();

    let out = Command::new(env!("CARGO_BIN_EXE_goghd"))
        .args(["--socket", sock_s, "--state", state_s])
        .output()
        .expect("running goghd");
    assert!(!out.status.success(), "goghd started despite a corrupt snapshot");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("state snapshot") && stderr.contains("state.json"),
        "error must name the snapshot file: {stderr:?}"
    );
    assert!(
        !stderr.contains("panicked"),
        "corrupt snapshot must be an error, not a panic: {stderr:?}"
    );

    // --fresh is the documented escape hatch: same file, clean start
    let daemon = spawn_daemon(&["--socket", sock_s, "--state", state_s, "--fresh"]);
    poll("daemon socket", || sock.exists().then_some(()));
    let r = request_unix(&sock, r#"{"cmd":"status"}"#);
    assert!(is_ok(&r), "{r}");
    drop(daemon);
    std::fs::remove_dir_all(&dir).ok();
}
