//! Integration tests: cross-module behaviour of the full system —
//! simulator × schedulers × optimizer × catalog, and (when artifacts
//! are built) the complete GOGH loop over PJRT.

use gogh::baselines::{GreedyScheduler, OracleScheduler, RandomScheduler};
use gogh::cluster::ClusterSpec;
use gogh::config::ExperimentConfig;
use gogh::coordinator::{GoghOptions, GoghScheduler, Scheduler, SimDriver};
use gogh::runtime::Engine;
use gogh::workload::{ThroughputOracle, Trace, TraceConfig};

fn small_trace(seed: u64, n: usize) -> (ThroughputOracle, Trace) {
    let oracle = ThroughputOracle::new(seed);
    let cfg = TraceConfig {
        n_jobs: n,
        mean_interarrival_s: 25.0,
        mean_work_s: 120.0,
        seed,
        ..Default::default()
    };
    let trace = Trace::generate(&cfg, &oracle);
    (oracle, trace)
}

fn driver(oracle: &ThroughputOracle, trace: Trace, seed: u64) -> SimDriver {
    SimDriver::new(
        ClusterSpec::balanced(2),
        oracle.clone(),
        trace,
        0.02,
        20.0,
        seed,
    )
    .unwrap()
}

#[test]
fn all_baselines_complete_the_same_trace() {
    let (oracle, trace) = small_trace(3, 8);
    let mut schedulers: Vec<Box<dyn Scheduler>> = vec![
        Box::new(RandomScheduler::new(3)),
        Box::new(GreedyScheduler::new()),
        Box::new(OracleScheduler::new(oracle.clone(), Default::default())),
    ];
    for s in schedulers.iter_mut() {
        let mut d = driver(&oracle, trace.clone(), 3);
        let report = d.run(s.as_mut()).unwrap();
        assert_eq!(report.jobs_completed, 8, "{} lost jobs", s.name());
        assert!(report.energy_joules > 0.0);
        assert!(report.total_energy_joules >= report.energy_joules);
    }
}

#[test]
fn oracle_ilp_meets_slos_at_lower_power_than_greedy() {
    // Objective (2a) minimizes instantaneous power subject to SLOs — so
    // the right comparisons are (i) SLO satisfaction vs random (which
    // ignores SLOs) and (ii) time-averaged busy power vs greedy (which
    // meets throughput by always grabbing the fastest, power-hungriest
    // GPUs). Energy-per-job is NOT what the objective optimizes (slower
    // but thriftier schedules trade JCT for watts).
    let (oracle, trace) = small_trace(5, 10);
    let mut d1 = driver(&oracle, trace.clone(), 5);
    let rand_report = d1.run(&mut RandomScheduler::new(5)).unwrap();
    let mut d2 = driver(&oracle, trace.clone(), 5);
    let greedy_report = d2.run(&mut GreedyScheduler::new()).unwrap();
    let mut d3 = driver(&oracle, trace, 5);
    let mut oracle_sched = OracleScheduler::new(oracle.clone(), Default::default());
    let oracle_report = d3.run(&mut oracle_sched).unwrap();

    // (i) SLOs: oracle must not be worse than random
    assert!(oracle_report.slo_deficit <= rand_report.slo_deficit + 1e-9);
    // (ii) mean busy power: oracle ≤ greedy (the energy objective)
    let watts = |r: &gogh::metrics::RunReport| r.energy_joules / r.sim_seconds.max(1e-9);
    assert!(
        watts(&oracle_report) <= watts(&greedy_report) * 1.05,
        "oracle {:.1} W vs greedy {:.1} W",
        watts(&oracle_report),
        watts(&greedy_report)
    );
}

#[test]
fn simulation_is_reproducible_across_runs() {
    let run = || {
        let (oracle, trace) = small_trace(7, 6);
        let mut d = driver(&oracle, trace, 7);
        d.run(&mut GreedyScheduler::new()).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.energy_joules, b.energy_joules);
    assert_eq!(a.slo_deficit, b.slo_deficit);
    assert_eq!(a.migrations, b.migrations);
    assert_eq!(a.mean_jct, b.mean_jct);
}

#[test]
fn config_drives_cluster_size() {
    let cfg = ExperimentConfig::from_json(
        r#"{"cluster": {"accel_mix": {"k80": 3, "v100": 1}}, "trace": {"n_jobs": 3}}"#,
    )
    .unwrap();
    let spec = ClusterSpec::mix(&cfg.cluster.accel_mix);
    assert_eq!(spec.len(), 4);
}

#[test]
fn cancellations_and_churn_drain_through_every_baseline() {
    // a trace with owner cancellations and accelerator maintenance
    // cycles: every baseline must drain it (completed + cancelled =
    // arrivals) through the event-driven driver.
    let oracle = ThroughputOracle::new(21);
    let cfg = TraceConfig {
        n_jobs: 10,
        mean_interarrival_s: 25.0,
        mean_work_s: 120.0,
        cancel_rate: 0.4,
        accel_churn: 2.0,
        seed: 21,
        ..Default::default()
    };
    let trace = Trace::generate(&cfg, &oracle);
    assert_eq!(trace.n_jobs(), 10);
    assert!(trace.len() > 10, "scenario events missing from the trace");
    let mut schedulers: Vec<Box<dyn Scheduler>> = vec![
        Box::new(RandomScheduler::new(21)),
        Box::new(GreedyScheduler::new()),
        Box::new(OracleScheduler::new(oracle.clone(), Default::default())),
    ];
    for s in schedulers.iter_mut() {
        let mut d = driver(&oracle, trace.clone(), 21);
        let report = d.run(s.as_mut()).unwrap();
        assert_eq!(
            report.jobs_completed + report.jobs_cancelled,
            report.jobs_total,
            "{} lost jobs",
            s.name()
        );
        assert!(report.sim_seconds < d.drain_limit_s, "{} timed out", s.name());
    }
}

#[test]
fn migration_cost_is_integrated_into_the_report() {
    let (oracle, trace) = small_trace(9, 8);
    let run = |cost: f64| {
        let mut d = SimDriver::new(
            ClusterSpec::balanced(1), // tight: 6 instances, forced moves
            oracle.clone(),
            trace.clone(),
            0.0,
            20.0,
            9,
        )
        .unwrap()
        .with_options(gogh::engine::EngineOptions::new().with_migration_cost(cost));
        d.run(&mut RandomScheduler::new(9)).unwrap()
    };
    let free = run(0.0);
    let charged = run(30.0);
    assert_eq!(free.migration_stall_s, 0.0);
    // the random policy reshuffles on every event → some job migrated
    assert!(charged.migration_stall_s > 0.0, "no restart penalty charged");
    assert_eq!(charged.jobs_completed, 8);
}

// ---------------------------------------------------------------------
// Estimator-free GOGH: the full decision path (sharding, estimate
// cache, ILP, catalog learning loop) without PJRT artifacts — these run
// everywhere, including CI.
// ---------------------------------------------------------------------

fn free_gogh(seed: u64, options: GoghOptions) -> (SimDriver, GoghScheduler) {
    let (oracle, trace) = small_trace(seed, 8);
    let d = driver(&oracle, trace, seed);
    let sched = GoghScheduler::without_engine(&oracle, options).unwrap();
    (d, sched)
}

#[test]
fn gogh_estimator_free_completes_and_tracks_errors() {
    let (mut d, mut sched) = free_gogh(
        19,
        GoghOptions {
            history_jobs: 12,
            seed: 19,
            ..Default::default()
        },
    );
    let report = d.run(&mut sched).unwrap();
    assert_eq!(report.jobs_completed, 8);
    // priors were scored against measurements even without P1/P2
    let mae = report.estimation_mae.expect("estimation MAE tracked");
    assert!(mae.is_finite() && mae >= 0.0);
    assert!(sched.catalog.n_measured() > 0);
    assert!(report.mean_solve_ms > 0.0);
    // estimate cache was exercised on the decision path
    let cache = sched.cache_stats();
    assert!(cache.hits > 0, "no cache hits: {cache:?}");
    assert!(cache.invalidations > 0, "cache never invalidated");
}

#[test]
fn estimate_cache_is_value_transparent_end_to_end() {
    // the memoized estimate matrix must never change a decision: cached
    // and uncached runs of the same trace are bit-identical
    let run = |cache: bool| {
        let (mut d, mut sched) = free_gogh(
            23,
            GoghOptions {
                history_jobs: 12,
                estimate_cache: cache,
                seed: 23,
                ..Default::default()
            },
        );
        d.run(&mut sched).unwrap()
    };
    let cached = run(true);
    let direct = run(false);
    assert_eq!(cached.energy_joules, direct.energy_joules);
    assert_eq!(cached.total_energy_joules, direct.total_energy_joules);
    assert_eq!(cached.migrations, direct.migrations);
    assert_eq!(cached.mean_jct, direct.mean_jct);
    assert_eq!(cached.slo_deficit, direct.slo_deficit);
    assert_eq!(cached.events, direct.events);
}

#[test]
fn sharded_decision_path_is_deterministic_and_drains() {
    for shards in [2usize, 4] {
        let run = || {
            let (mut d, mut sched) = free_gogh(
                29,
                GoghOptions {
                    history_jobs: 12,
                    shards,
                    seed: 29,
                    ..Default::default()
                },
            );
            let report = d.run(&mut sched).unwrap();
            let routed: usize = sched.shard_stats().iter().map(|s| s.routed).sum();
            (report, routed)
        };
        let (a, routed_a) = run();
        let (b, routed_b) = run();
        assert_eq!(a.jobs_completed, 8, "P={shards} lost jobs");
        assert_eq!(a.energy_joules, b.energy_joules, "P={shards} nondeterministic");
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.mean_jct, b.mean_jct);
        assert_eq!(routed_a, routed_b);
        assert!(routed_a > 0, "P={shards}: no arrival was shard-routed");
    }
}

#[test]
fn sharded_gogh_survives_churn_and_cancellations() {
    let oracle = ThroughputOracle::new(31);
    let cfg = TraceConfig {
        n_jobs: 10,
        mean_interarrival_s: 25.0,
        mean_work_s: 120.0,
        cancel_rate: 0.3,
        accel_churn: 2.0,
        seed: 31,
        ..Default::default()
    };
    let trace = Trace::generate(&cfg, &oracle);
    let mut d = driver(&oracle, trace, 31);
    let mut sched = GoghScheduler::without_engine(
        &oracle,
        GoghOptions {
            history_jobs: 12,
            shards: 3,
            seed: 31,
            ..Default::default()
        },
    )
    .unwrap();
    let report = d.run(&mut sched).unwrap();
    assert_eq!(
        report.jobs_completed + report.jobs_cancelled,
        report.jobs_total,
        "sharded gogh lost jobs under churn"
    );
    assert!(report.sim_seconds < d.drain_limit_s, "run failed to drain");
}

#[test]
fn gogh_without_artifacts_from_config() {
    let mut cfg = ExperimentConfig::default();
    cfg.trace.n_jobs = 4;
    cfg.trace.mean_work_s = 100.0;
    cfg.trace.mean_interarrival_s = 20.0;
    cfg.gogh.shards = 2;
    let mut sys = gogh::Gogh::without_engine(&cfg).unwrap();
    let report = sys.run().unwrap();
    assert_eq!(report.jobs_completed, 4);
}

#[test]
fn gogh_builder_matches_legacy_constructors() {
    // Gogh::builder is the one construction path; the legacy
    // constructors are thin wrappers over it, so both spellings must
    // produce bit-identical runs.
    let mut cfg = ExperimentConfig::default();
    cfg.trace.n_jobs = 4;
    cfg.trace.mean_work_s = 100.0;
    cfg.trace.mean_interarrival_s = 20.0;
    cfg.gogh.shards = 2;
    let mut legacy = gogh::Gogh::without_engine(&cfg).unwrap();
    let mut built = gogh::Gogh::builder(&cfg).estimator_free().build().unwrap();
    assert_eq!(legacy.backend_name(), built.backend_name());
    let a = legacy.run().unwrap();
    let b = built.run().unwrap();
    assert_eq!(a.jobs_completed, b.jobs_completed);
    assert_eq!(a.energy_joules, b.energy_joules);
    assert_eq!(a.mean_jct, b.mean_jct);
    assert_eq!(a.events, b.events);
    assert_eq!(a.row(), b.row());
}

#[test]
fn topology_routed_path_is_deterministic_and_drains() {
    // two-level routing (2 groups × 2 shards): the router picks one
    // group per arrival and only that group's shards solve, yet the
    // run stays deterministic and loses no jobs
    let run = || {
        let (mut d, mut sched) = free_gogh(
            37,
            GoghOptions {
                history_jobs: 12,
                shards: 2,
                topology_groups: 2,
                seed: 37,
                ..Default::default()
            },
        );
        let report = d.run(&mut sched).unwrap();
        let routed: usize = sched.shard_stats().iter().map(|s| s.routed).sum();
        (report, routed)
    };
    let (a, routed_a) = run();
    let (b, routed_b) = run();
    assert_eq!(a.jobs_completed, 8, "topology path lost jobs");
    assert_eq!(a.energy_joules, b.energy_joules, "topology path nondeterministic");
    assert_eq!(a.migrations, b.migrations);
    assert_eq!(a.mean_jct, b.mean_jct);
    assert_eq!(routed_a, routed_b);
    assert!(routed_a > 0, "no arrival was topology-routed");
}

// ---------------------------------------------------------------------
// PJRT-dependent tests (skip when artifacts are absent)
// ---------------------------------------------------------------------

fn engine() -> Option<std::sync::Arc<Engine>> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::load("artifacts").unwrap())
}

#[test]
fn gogh_full_loop_completes_and_learns() {
    let Some(engine) = engine() else { return };
    let (oracle, trace) = small_trace(11, 6);
    let mut d = driver(&oracle, trace, 11);
    let mut sched = GoghScheduler::new(
        &engine,
        &oracle,
        GoghOptions {
            history_jobs: 12,
            seed: 11,
            ..Default::default()
        },
    )
    .unwrap();
    let report = d.run(&mut sched).unwrap();
    assert_eq!(report.jobs_completed, 6);
    // the estimator must have been scored against measurements
    let mae = report.estimation_mae.expect("estimation MAE tracked");
    assert!(mae.is_finite() && mae >= 0.0);
    assert!(mae < 0.3, "estimation MAE suspiciously large: {mae}");
    // catalog accumulated measured + refined records
    assert!(sched.catalog.n_measured() > 0);
    assert!(report.mean_solve_ms > 0.0);
    assert!(report.mean_p1_ms > 0.0);
}

#[test]
fn gogh_refinement_improves_estimation_over_p1_only() {
    let Some(engine) = engine() else { return };
    let run = |refine: bool| {
        let (oracle, trace) = small_trace(13, 8);
        let mut d = driver(&oracle, trace, 13);
        let mut sched = GoghScheduler::new(
            &engine,
            &oracle,
            GoghOptions {
                history_jobs: 16,
                enable_refinement: refine,
                seed: 13,
                ..Default::default()
            },
        )
        .unwrap();
        d.run(&mut sched).unwrap().estimation_mae.unwrap()
    };
    let with = run(true);
    let without = run(false);
    // Eq. 3/4 refinement should not make estimates meaningfully worse;
    // typically it improves them. Allow slack for noise.
    assert!(
        with <= without * 1.15,
        "refinement hurt: with={with} without={without}"
    );
}

#[test]
fn gogh_with_exploration_still_completes() {
    let Some(engine) = engine() else { return };
    let (oracle, trace) = small_trace(17, 6);
    let mut d = driver(&oracle, trace, 17);
    let mut sched = GoghScheduler::new(
        &engine,
        &oracle,
        GoghOptions {
            history_jobs: 12,
            exploration_epsilon: 1.0, // explore on every allocation round
            seed: 17,
            ..Default::default()
        },
    )
    .unwrap();
    let report = d.run(&mut sched).unwrap();
    assert_eq!(report.jobs_completed, 6);
    // exploration must not break placement invariants (jobs all finish)
    assert!(report.estimation_mae.is_some());
}

#[test]
fn gogh_from_config_runs() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        return;
    }
    let mut cfg = ExperimentConfig::default();
    cfg.trace.n_jobs = 4;
    cfg.trace.mean_work_s = 100.0;
    cfg.trace.mean_interarrival_s = 20.0;
    let mut sys = gogh::Gogh::from_config(&cfg).unwrap();
    let report = sys.run().unwrap();
    assert_eq!(report.jobs_completed, 4);
}
