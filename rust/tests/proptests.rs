//! Property-based tests (seeded random-case loops — the offline
//! substitute for proptest, see Cargo.toml): solver correctness against
//! brute force, Problem-1 solution invariants, catalog/placement
//! algebra, and encoding round-trips, each over hundreds of random
//! instances.

use std::collections::{BTreeMap, HashMap};

use gogh::catalog::{Catalog, EstimateKey};
use gogh::cluster::{AccelId, Cluster, ClusterSpec, Placement, PlacementDelta, PlacementOp};
use gogh::ilp::branch_bound::{solve_ilp, BnbConfig, BnbStatus};
use gogh::ilp::model::{Model, ObjSense, Sense};
use gogh::ilp::problem1::{build_problem1, solve_problem1, Problem1Builder, Problem1Input};
use gogh::util::Rng;
use gogh::workload::{
    encoding, AccelType, Combo, JobId, JobSpec, ModelFamily, ThroughputOracle, ACCEL_TYPES,
    FAMILIES,
};

/// Brute-force optimum of a small binary program.
fn brute_force(model: &Model) -> Option<f64> {
    let n = model.n_vars();
    assert!(n <= 14);
    let mut best: Option<f64> = None;
    for mask in 0u32..(1 << n) {
        let x: Vec<f64> = (0..n).map(|i| ((mask >> i) & 1) as f64).collect();
        if model.is_feasible(&x, 1e-9) {
            let obj = model.objective_value(&x);
            best = Some(match (best, model.obj_sense) {
                (None, _) => obj,
                (Some(b), ObjSense::Minimize) => b.min(obj),
                (Some(b), ObjSense::Maximize) => b.max(obj),
            });
        }
    }
    best
}

#[test]
fn prop_bnb_matches_brute_force_on_random_binary_programs() {
    let mut rng = Rng::seed_from_u64(101);
    for case in 0..150 {
        let n = rng.range_usize(2, 9);
        let sense = if rng.bool(0.5) {
            ObjSense::Minimize
        } else {
            ObjSense::Maximize
        };
        let mut m = Model::new(sense);
        let vars: Vec<_> = (0..n)
            .map(|i| m.add_binary(format!("x{i}"), rng.range_f64(-5.0, 5.0)))
            .collect();
        for c in 0..rng.range_usize(1, 5) {
            let mut terms: Vec<_> = vec![];
            for &v in &vars {
                if rng.bool(0.6) {
                    terms.push((v, rng.range_f64(-3.0, 3.0)));
                }
            }
            if terms.is_empty() {
                continue;
            }
            let s = match rng.range_usize(0, 3) {
                0 => Sense::Le,
                1 => Sense::Ge,
                _ => Sense::Eq,
            };
            // rhs reachable by some assignment to avoid mostly-infeasible cases
            let lhs_max: f64 = terms.iter().map(|(_, k)| k.max(0.0)).sum();
            let lhs_min: f64 = terms.iter().map(|(_, k)| k.min(0.0)).sum();
            let rhs = if s == Sense::Eq {
                // pick an achievable subset sum
                let x: Vec<bool> = (0..n).map(|_| rng.bool(0.5)).collect();
                terms.iter().map(|&(v, k)| if x[v.0] { k } else { 0.0 }).sum()
            } else {
                rng.range_f64(lhs_min, lhs_max.max(lhs_min + 0.1))
            };
            m.add_constraint(format!("c{c}"), terms, s, rhs);
        }
        let expect = brute_force(&m);
        let got = solve_ilp(&m, &BnbConfig::default());
        match expect {
            None => assert_eq!(
                got.status,
                BnbStatus::Infeasible,
                "case {case}: solver found {:?} but brute force says infeasible",
                got.objective
            ),
            Some(opt) => {
                assert!(
                    matches!(got.status, BnbStatus::Optimal | BnbStatus::Feasible),
                    "case {case}: {:?}",
                    got.status
                );
                assert!(
                    (got.objective - opt).abs() < 1e-6,
                    "case {case}: solver {} vs brute force {opt}",
                    got.objective
                );
            }
        }
    }
}

#[test]
fn prop_problem1_solutions_always_satisfy_constraints() {
    let mut rng = Rng::seed_from_u64(202);
    for case in 0..40 {
        let oracle = ThroughputOracle::new(case);
        let n_jobs = rng.range_usize(2, 10) as u32;
        let jobs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| {
                let f = FAMILIES[rng.range_usize(0, FAMILIES.len())];
                let b = f.batch_sizes()[rng.range_usize(0, f.batch_sizes().len())];
                let mut j = JobSpec {
                    id: JobId(i),
                    family: f,
                    batch_size: b,
                    replication: 1,
                    min_throughput: 0.0,
                    distributability: rng.range_u32_inclusive(1, 2),
                    work: 10.0,
                    priority: Default::default(),
                    elastic: false,
                    inference: None,
                };
                j.min_throughput = rng.range_f64(0.1, 0.5) * oracle.solo(&j, AccelType::P100);
                j
            })
            .collect();
        let per_type = rng.range_u32_inclusive(1, 3);
        let counts: BTreeMap<AccelType, u32> =
            ACCEL_TYPES.iter().map(|&a| (a, per_type)).collect();
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / AccelType::V100.base_speed();
        let input = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &cap,
            max_pairs_per_job: rng.range_usize(0, 4),
            slack_penalty: Some(2000.0),
            throughput_bonus: 300.0,
            now_s: 0.0,
            power: Default::default(),
        };
        let sol = solve_problem1(&input, &BnbConfig::default());
        assert!(
            matches!(sol.status, BnbStatus::Optimal | BnbStatus::Feasible),
            "case {case}: {:?}",
            sol.status
        );
        // (2f aggregated) per-type capacity
        for &a in ACCEL_TYPES.iter() {
            let used: u32 = sol
                .assignments
                .iter()
                .filter(|(aa, _, _)| *aa == a)
                .map(|(_, _, m)| *m)
                .sum();
            assert!(used <= counts[&a], "case {case}: type {a:?} over-used");
        }
        // (2c) distributability + (2b/2e modulo declared violations)
        for j in &jobs {
            let placements: u32 = sol
                .assignments
                .iter()
                .filter(|(_, c, _)| c.contains(j.id))
                .map(|(_, _, m)| *m)
                .sum();
            assert!(
                placements <= j.distributability,
                "case {case}: job {} exceeds D_j",
                j.id
            );
            if !sol.violated_jobs.contains(&j.id) {
                assert!(placements >= 1, "case {case}: job {} uncovered", j.id);
                let total: f64 = sol
                    .assignments
                    .iter()
                    .filter(|(_, c, _)| c.contains(j.id))
                    .map(|(a, c, m)| thr(*a, j.id, c) * *m as f64)
                    .sum();
                assert!(
                    total >= j.min_throughput - 1e-6,
                    "case {case}: job {} SLO unmet without declared violation",
                    j.id
                );
            }
        }
        // combos fit capacity θ_a = 2
        for (_, c, _) in &sol.assignments {
            assert!(c.len() <= 2);
        }
    }
}

#[test]
fn prop_catalog_refinement_average_is_mean_of_pushed_values() {
    let mut rng = Rng::seed_from_u64(303);
    for _ in 0..100 {
        let mut catalog = Catalog::new();
        let key = EstimateKey {
            accel: ACCEL_TYPES[rng.range_usize(0, 6)],
            job: JobId(rng.range_u32_inclusive(0, 50)),
            combo: Combo::Solo(JobId(1)),
        };
        let initial = rng.range_f64(0.0, 1.0);
        catalog.write_initial(key, initial);
        let mut values = vec![initial];
        for round in 1..rng.range_usize(2, 12) {
            let v = rng.range_f64(0.0, 1.0);
            catalog.push_refinement(key, v, round as u32);
            values.push(v);
        }
        let expect = values.iter().sum::<f64>() / values.len() as f64;
        assert!((catalog.value(&key).unwrap() - expect).abs() < 1e-12);
    }
}

#[test]
fn prop_placement_never_double_books_a_job_per_accel() {
    let mut rng = Rng::seed_from_u64(404);
    for _ in 0..100 {
        let mut p = Placement::new();
        let accels: Vec<AccelId> = (0..6)
            .map(|s| AccelId {
                server: s,
                accel: ACCEL_TYPES[rng.range_usize(0, 6)],
            })
            .collect();
        for _ in 0..30 {
            let a = accels[rng.range_usize(0, accels.len())];
            match rng.range_usize(0, 3) {
                0 => p.assign(a, Combo::Solo(JobId(rng.range_u32_inclusive(0, 9)))),
                1 => {
                    let j1 = JobId(rng.range_u32_inclusive(0, 9));
                    let mut j2 = JobId(rng.range_u32_inclusive(0, 9));
                    if j1 == j2 {
                        j2 = JobId((j2.0 + 1) % 10);
                    }
                    p.assign(a, Combo::pair(j1, j2));
                }
                _ => p.remove_job(JobId(rng.range_u32_inclusive(0, 9))),
            }
            // invariant: by_job and by_accel agree
            for (aid, combo) in p.iter() {
                for j in combo.jobs() {
                    assert!(p.accels_of(j).contains(aid));
                }
            }
            for j in (0..10).map(JobId) {
                for aid in p.accels_of(j) {
                    assert!(p.combo_on(*aid).map_or(false, |c| c.contains(j)));
                }
                // a job appears at most once per accel
                let mut seen = std::collections::HashSet::new();
                for aid in p.accels_of(j) {
                    assert!(seen.insert(*aid), "job {j} twice on {aid}");
                }
            }
        }
    }
}

/// Shared helpers for the placement-delta properties.
fn delta_test_cluster(n_jobs: u32) -> Cluster {
    let mut c = Cluster::new(ClusterSpec::balanced(1)); // 6 instances
    for i in 0..n_jobs {
        c.add_job(JobSpec {
            id: JobId(i),
            family: FAMILIES[i as usize % FAMILIES.len()],
            batch_size: FAMILIES[i as usize % FAMILIES.len()].batch_sizes()[0],
            replication: 1,
            min_throughput: 0.0,
            distributability: 2,
            work: 100.0,
            priority: Default::default(),
            elastic: false,
            inference: None,
        });
    }
    c
}

/// Valid-by-construction random placement: every job on ≤ 2 instances,
/// each instance hosting at most one solo/pair combo.
fn random_placement(rng: &mut Rng, accels: &[AccelId], n_jobs: u32) -> Placement {
    let mut p = Placement::new();
    let mut usage: HashMap<JobId, u32> = HashMap::new();
    for &a in accels {
        let mut free: Vec<JobId> = (0..n_jobs)
            .map(JobId)
            .filter(|j| usage.get(j).copied().unwrap_or(0) < 2)
            .collect();
        match rng.range_usize(0, 3) {
            0 => {} // leave empty
            1 if !free.is_empty() => {
                let j = free.swap_remove(rng.range_usize(0, free.len()));
                *usage.entry(j).or_default() += 1;
                p.assign(a, Combo::Solo(j));
            }
            _ if free.len() >= 2 => {
                let j1 = free.swap_remove(rng.range_usize(0, free.len()));
                let j2 = free.swap_remove(rng.range_usize(0, free.len()));
                *usage.entry(j1).or_default() += 1;
                *usage.entry(j2).or_default() += 1;
                p.assign(a, Combo::pair(j1, j2));
            }
            _ => {}
        }
    }
    p
}

/// Placement sanity: by_accel/by_job agree, no job twice on one accel,
/// distributability respected, nothing on a down accelerator.
fn assert_placement_invariants(c: &Cluster, n_jobs: u32) {
    for (aid, combo) in c.placement.iter() {
        assert!(combo.len() <= 2);
        assert!(!c.is_accel_down(*aid), "combo on down accel {aid}");
        for j in combo.jobs() {
            assert!(c.placement.accels_of(j).contains(aid));
        }
    }
    for j in (0..n_jobs).map(JobId) {
        let accels = c.placement.accels_of(j);
        let mut seen = std::collections::HashSet::new();
        for aid in accels {
            assert!(seen.insert(*aid), "job {j} double-booked on {aid}");
            assert!(c.placement.combo_on(*aid).map_or(false, |cb| cb.contains(j)));
        }
        let d = c.job(j).map(|s| s.distributability as usize).unwrap_or(2);
        assert!(accels.len() <= d, "job {j} on {} > D_j instances", accels.len());
    }
}

#[test]
fn prop_delta_diff_apply_equals_full_replacement() {
    let mut rng = Rng::seed_from_u64(808);
    for case in 0..150 {
        let n_jobs = rng.range_u32_inclusive(1, 10);
        let mut c = delta_test_cluster(n_jobs);
        let accels = c.spec.accels.clone();
        c.placement = random_placement(&mut rng, &accels, n_jobs);
        let target = random_placement(&mut rng, &accels, n_jobs);
        let delta = PlacementDelta::diff(&c.placement, &target);
        let outcome = c
            .apply_delta(&delta)
            .unwrap_or_else(|e| panic!("case {case}: valid diff rejected: {e}"));
        assert_eq!(
            c.placement.diff_count(&target),
            0,
            "case {case}: delta apply != replacement"
        );
        // an instance whose combo changes costs one move but two ops
        // (evict + assign), so moves ≤ ops, with equality on emptiness
        assert!(outcome.moves <= delta.len(), "case {case}: moves > ops");
        assert_eq!(delta.is_empty(), outcome.moves == 0, "case {case}");
        assert_placement_invariants(&c, n_jobs);
        // a second diff against the reached state is empty (idempotence)
        assert!(PlacementDelta::diff(&c.placement, &target).is_empty());
    }
}

#[test]
fn prop_random_op_sequences_never_double_book() {
    let mut rng = Rng::seed_from_u64(909);
    for _case in 0..60 {
        let n_jobs = rng.range_u32_inclusive(2, 10);
        let mut c = delta_test_cluster(n_jobs);
        let accels = c.spec.accels.clone();
        for _step in 0..40 {
            let a = accels[rng.range_usize(0, accels.len())];
            let j1 = JobId(rng.range_u32_inclusive(0, n_jobs - 1));
            let j2 = JobId(rng.range_u32_inclusive(0, n_jobs - 1));
            let op = match rng.range_usize(0, 4) {
                0 => PlacementOp::Assign {
                    accel: a,
                    combo: Combo::Solo(j1),
                },
                1 => PlacementOp::Assign {
                    accel: a,
                    combo: Combo::pair(j1, j2),
                },
                2 => PlacementOp::Evict { accel: a },
                _ => PlacementOp::Migrate {
                    job: j1,
                    from: accels[rng.range_usize(0, accels.len())],
                    to: a,
                },
            };
            let before = c.placement.clone();
            let delta = PlacementDelta { ops: vec![op] };
            match c.apply_delta(&delta) {
                Ok(_) => {}
                Err(_) => {
                    // rejected deltas must not leak partial state
                    assert_eq!(c.placement.diff_count(&before), 0);
                }
            }
            assert_placement_invariants(&c, n_jobs);
        }
    }
}

#[test]
fn prop_suspend_resume_op_sequences_preserve_invariants() {
    // Random op sequences mixing the preemption primitives (Suspend /
    // Resume) with assigns, evicts and migrates, on a cluster with a
    // few instances down: applied deltas never double-book an instance,
    // never lose a job (every job stays registered and is never both
    // placed and suspended), and never resume onto a down instance;
    // rejected deltas leak neither placement nor suspension state.
    let mut rng = Rng::seed_from_u64(9911);
    for _case in 0..60 {
        let n_jobs = rng.range_u32_inclusive(2, 10);
        let mut c = delta_test_cluster(n_jobs);
        let accels = c.spec.accels.clone();
        for _ in 0..rng.range_usize(0, 3) {
            c.set_accel_down(accels[rng.range_usize(0, accels.len())]);
        }
        for _step in 0..60 {
            let a = accels[rng.range_usize(0, accels.len())];
            let j1 = JobId(rng.range_u32_inclusive(0, n_jobs - 1));
            let j2 = JobId(rng.range_u32_inclusive(0, n_jobs - 1));
            let op = match rng.range_usize(0, 6) {
                0 => PlacementOp::Assign {
                    accel: a,
                    combo: Combo::Solo(j1),
                },
                1 => PlacementOp::Assign {
                    accel: a,
                    combo: Combo::pair(j1, j2),
                },
                2 => PlacementOp::Evict { accel: a },
                3 => PlacementOp::Migrate {
                    job: j1,
                    from: accels[rng.range_usize(0, accels.len())],
                    to: a,
                },
                4 => PlacementOp::Suspend { job: j1 },
                _ => PlacementOp::Resume { job: j1, accel: a },
            };
            let before = c.placement.clone();
            let suspended_before = c.suspended_job_ids();
            match c.apply_delta(&PlacementDelta { ops: vec![op] }) {
                Ok(_) => {
                    if let PlacementOp::Resume { job, accel } = op {
                        assert!(!c.is_accel_down(accel), "job {job} resumed onto down {accel}");
                        assert!(c.placement.accels_of(job).contains(&accel));
                        assert!(!c.is_suspended(job));
                    }
                }
                Err(_) => {
                    // rejected deltas must not leak partial state
                    assert_eq!(c.placement.diff_count(&before), 0);
                    assert_eq!(c.suspended_job_ids(), suspended_before);
                }
            }
            assert_placement_invariants(&c, n_jobs);
            for j in (0..n_jobs).map(JobId) {
                assert!(c.job(j).is_some(), "job {j} lost");
                if c.is_suspended(j) {
                    assert!(!c.placement.is_placed(j), "job {j} both suspended and placed");
                }
            }
        }
    }
}

#[test]
fn prop_power_capped_op_sequences_respect_cap_and_invariants() {
    // Random SetPowerState + placement ops under a cluster power cap:
    // after trim_to_power_cap, applied deltas never push worst-case
    // draw over the cap, rejected deltas never leak placement or state,
    // and the placement invariants hold throughout.
    use gogh::power::PowerState;
    let mut rng = Rng::seed_from_u64(7007);
    for _case in 0..40 {
        let n_jobs = rng.range_u32_inclusive(2, 10);
        let mut c = delta_test_cluster(n_jobs);
        let cap = rng.range_f64(200.0, 500.0);
        c.set_power_cap(Some(cap));
        let accels = c.spec.accels.clone();
        for _step in 0..40 {
            let a = accels[rng.range_usize(0, accels.len())];
            let j1 = JobId(rng.range_u32_inclusive(0, n_jobs - 1));
            let j2 = JobId(rng.range_u32_inclusive(0, n_jobs - 1));
            let op = match rng.range_usize(0, 5) {
                0 => PlacementOp::Assign {
                    accel: a,
                    combo: Combo::Solo(j1),
                },
                1 => PlacementOp::Assign {
                    accel: a,
                    combo: Combo::pair(j1, j2),
                },
                2 => PlacementOp::Evict { accel: a },
                3 => PlacementOp::SetPowerState {
                    accel: a,
                    state: PowerState::ALL[rng.range_usize(0, 3)],
                },
                _ => PlacementOp::Migrate {
                    job: j1,
                    from: accels[rng.range_usize(0, accels.len())],
                    to: a,
                },
            };
            let before = c.placement.clone();
            let states_before: Vec<PowerState> =
                accels.iter().map(|&a| c.power_state(a)).collect();
            let delta = c.trim_to_power_cap(&PlacementDelta { ops: vec![op] });
            match c.apply_delta(&delta) {
                Ok(_) => {
                    assert!(
                        c.worst_case_watts() <= cap + 1e-6,
                        "worst {} > cap {cap}",
                        c.worst_case_watts()
                    );
                }
                Err(e) => {
                    // the trim removed every cap breach, so a residual
                    // error is a validity one — and nothing may leak
                    assert!(!e.to_string().contains("power cap"), "{e}");
                    assert_eq!(c.placement.diff_count(&before), 0);
                    let states_after: Vec<PowerState> =
                        accels.iter().map(|&a| c.power_state(a)).collect();
                    assert_eq!(states_after, states_before);
                }
            }
            assert_placement_invariants(&c, n_jobs);
        }
    }
}

#[test]
fn prop_p1_row_is_injective_in_its_fields() {
    // distinct (family, batch, accel) tuples must produce distinct rows —
    // the encoding must not alias information.
    let mut rng = Rng::seed_from_u64(505);
    let mut seen: HashMap<Vec<u32>, (ModelFamily, u32, usize)> = Default::default();
    for _ in 0..300 {
        let f = FAMILIES[rng.range_usize(0, FAMILIES.len())];
        let b = f.batch_sizes()[rng.range_usize(0, f.batch_sizes().len())];
        let ai = rng.range_usize(0, 6);
        let p = encoding::psi(f, b, 1);
        let row = encoding::p1_row(&p, &encoding::PSI_EMPTY, ACCEL_TYPES[ai], 0.5, 0.0, &p);
        let bits: Vec<u32> = row.iter().map(|x| x.to_bits()).collect();
        if let Some(&(f2, b2, ai2)) = seen.get(&bits) {
            assert_eq!((f2, b2, ai2), (f, b, ai), "row collision");
        }
        seen.insert(bits, (f, b, ai));
    }
}

#[test]
fn prop_oracle_pair_is_never_faster_than_solo() {
    let mut rng = Rng::seed_from_u64(606);
    for seed in 0..20 {
        let oracle = ThroughputOracle::new(seed);
        for _ in 0..20 {
            let f1 = FAMILIES[rng.range_usize(0, FAMILIES.len())];
            let f2 = FAMILIES[rng.range_usize(0, FAMILIES.len())];
            let j1 = JobSpec {
                id: JobId(1),
                family: f1,
                batch_size: f1.batch_sizes()[rng.range_usize(0, f1.batch_sizes().len())],
                replication: 1,
                min_throughput: 0.0,
                distributability: 1,
                work: 1.0,
                priority: Default::default(),
                elastic: false,
                inference: None,
            };
            let j2 = JobSpec {
                id: JobId(2),
                family: f2,
                batch_size: f2.batch_sizes()[rng.range_usize(0, f2.batch_sizes().len())],
                replication: 1,
                min_throughput: 0.0,
                distributability: 1,
                work: 1.0,
                priority: Default::default(),
                elastic: false,
                inference: None,
            };
            for &a in ACCEL_TYPES.iter() {
                let (t1, t2) = oracle.pair(&j1, &j2, a);
                assert!(t1 <= oracle.solo(&j1, a) + 1e-12);
                assert!(t2 <= oracle.solo(&j2, a) + 1e-12);
                assert!(t1 > 0.0 && t2 > 0.0);
            }
        }
    }
}

#[test]
fn prop_refine_queries_never_contain_round_labels() {
    // Random catalogs + random measurement rounds: no P2 query row may
    // carry any of the round's measured targets in an *estimate* slot
    // (p2_row layout: 28,29 = est_a1, 32,33 = est_a2; 30,31 are the
    // measurement features and legitimately carry this round's labels).
    // Prior estimates are drawn below 0.7 and every prior chain tops out
    // near 1.05, while measured targets live in [2, 3] — disjoint ranges
    // make leakage unambiguous.
    use gogh::cluster::Measurement;
    use gogh::coordinator::refinement::build_refine_queries;
    let mut rng = Rng::seed_from_u64(1313);
    for case in 0..60 {
        let mut catalog = Catalog::new();
        let n_jobs = rng.range_u32_inclusive(2, 8);
        for j in 0..n_jobs {
            let f = FAMILIES[rng.range_usize(0, FAMILIES.len())];
            let b = f.batch_sizes()[rng.range_usize(0, f.batch_sizes().len())];
            catalog.register_job(JobId(j), encoding::psi(f, b, 1));
        }
        // random prior estimates (never ≥ 0.7)
        for _ in 0..rng.range_usize(0, 12) {
            let a = ACCEL_TYPES[rng.range_usize(0, ACCEL_TYPES.len())];
            let j1 = JobId(rng.range_u32_inclusive(0, n_jobs - 1));
            let combo = if rng.bool(0.5) {
                Combo::Solo(j1)
            } else {
                let j2 = JobId(rng.range_u32_inclusive(0, n_jobs - 1));
                if j2 == j1 {
                    Combo::Solo(j1)
                } else {
                    Combo::pair(j1, j2)
                }
            };
            catalog.write_initial(
                EstimateKey {
                    accel: a,
                    job: j1,
                    combo,
                },
                rng.range_f64(0.05, 0.69),
            );
        }
        // the round: distinct jobs, solo or paired; distributed jobs
        // (distributability 2) occasionally host the SAME combo on a
        // second instance of a different accel type — the case where a
        // fresh measurement exists on the query's target type a2 and
        // must still not surface in the estimate slots
        let mut free: Vec<JobId> = (0..n_jobs).map(JobId).collect();
        let mut ms: Vec<Measurement> = vec![];
        let mut server = 0;
        while free.len() >= 2 {
            let a = ACCEL_TYPES[rng.range_usize(0, ACCEL_TYPES.len())];
            let aid = AccelId { server, accel: a };
            server += 1;
            let second_aid = if rng.bool(0.4) {
                let a2 = ACCEL_TYPES[rng.range_usize(0, ACCEL_TYPES.len())];
                let aid2 = AccelId {
                    server,
                    accel: a2,
                };
                server += 1;
                Some(aid2)
            } else {
                None
            };
            if rng.bool(0.5) {
                let j = free.swap_remove(rng.range_usize(0, free.len()));
                for aid in std::iter::once(aid).chain(second_aid) {
                    ms.push(Measurement {
                        job: j,
                        combo: Combo::Solo(j),
                        accel: aid,
                        throughput: rng.range_f64(2.0, 3.0),
                        at: 1.0,
                    });
                }
            } else {
                let j1 = free.swap_remove(rng.range_usize(0, free.len()));
                let j2 = free.swap_remove(rng.range_usize(0, free.len()));
                let combo = Combo::pair(j1, j2);
                for aid in std::iter::once(aid).chain(second_aid) {
                    for j in [j1, j2] {
                        // occasionally drop a co-runner's measurement:
                        // the missing slot must be encoded as a prior
                        if j == j2 && rng.bool(0.2) {
                            continue;
                        }
                        ms.push(Measurement {
                            job: j,
                            combo,
                            accel: aid,
                            throughput: rng.range_f64(2.0, 3.0),
                            at: 1.0,
                        });
                    }
                }
            }
        }
        if ms.is_empty() {
            continue;
        }
        // the coordinator records the round's measurements first
        for m in &ms {
            catalog.record_measurement(
                EstimateKey {
                    accel: m.accel.accel,
                    job: m.job,
                    combo: m.combo,
                },
                m.throughput,
            );
        }
        let queries = build_refine_queries(&catalog, &ms);
        for (qi, q) in queries.iter().enumerate() {
            for slot in [28usize, 29, 32, 33] {
                assert!(
                    q.x[slot] < 2.0,
                    "case {case} query {qi}: estimate slot {slot} carries a \
                     measured label ({})",
                    q.x[slot]
                );
            }
        }
    }
}

#[test]
fn prop_autoscaling_deltas_preserve_cluster_invariants() {
    // Random mixed clusters with random valid placements: whatever the
    // replica autoscaler emits must apply cleanly (no double-booked
    // instance, no distributability overshoot) and never drop a live
    // placed serving job below one replica.
    use gogh::coordinator::{GoghOptions, GoghScheduler};
    use gogh::workload::InferenceSpec;
    let mut rng = Rng::seed_from_u64(2025);
    for case in 0..40 {
        let per_type = rng.range_u32_inclusive(1, 3);
        let mut c = Cluster::new(ClusterSpec::balanced(per_type));
        let n_jobs = rng.range_u32_inclusive(1, 8);
        for i in 0..n_jobs {
            let f = FAMILIES[rng.range_usize(0, FAMILIES.len())];
            let mut j = JobSpec {
                id: JobId(i),
                family: f,
                batch_size: f.batch_sizes()[rng.range_usize(0, f.batch_sizes().len())],
                replication: 1,
                min_throughput: 0.0,
                distributability: rng.range_u32_inclusive(2, 4),
                work: 500.0,
                priority: Default::default(),
                elastic: false,
                inference: None,
            };
            if rng.bool(0.7) {
                j.inference = Some(InferenceSpec {
                    base_rate: rng.range_f64(0.5, 40.0),
                    diurnal_amplitude: rng.range_f64(0.0, 0.4),
                    diurnal_phase_s: rng.range_f64(0.0, 86_400.0),
                    latency_slo_s: rng.range_f64(0.05, 2.0),
                });
            } else {
                j.min_throughput = 0.1;
            }
            c.add_job(j);
        }
        // random valid placement: each job on 1..=D_j instances, solo or
        // paired, never twice on one instance
        let accels = c.spec.accels.clone();
        let mut free: Vec<AccelId> = accels.clone();
        rng.shuffle(&mut free);
        for i in 0..n_jobs {
            let d = c.job(JobId(i)).unwrap().distributability;
            let want = rng.range_u32_inclusive(0, d.min(3));
            for _ in 0..want {
                let Some(a) = free.pop() else { break };
                c.placement.assign(a, Combo::Solo(JobId(i)));
            }
        }
        // sprinkle a few pairs among placed jobs
        if n_jobs >= 2 {
            for _ in 0..rng.range_usize(0, 3) {
                let (Some(a), j1, j2) = (
                    free.pop(),
                    JobId(rng.range_u32_inclusive(0, n_jobs - 1)),
                    JobId(rng.range_u32_inclusive(0, n_jobs - 1)),
                ) else {
                    break;
                };
                if j1 == j2 {
                    continue;
                }
                let room = |j: JobId| {
                    (c.placement.accels_of(j).len() as u32)
                        < c.job(j).map_or(0, |s| s.distributability)
                };
                if room(j1) && room(j2) {
                    c.placement.assign(a, Combo::pair(j1, j2));
                }
            }
        }
        let placed_before: Vec<JobId> = (0..n_jobs)
            .map(JobId)
            .filter(|&j| c.placement.is_placed(j))
            .collect();
        let oracle = ThroughputOracle::new(case as u64);
        let mut sched = GoghScheduler::without_engine(
            &oracle,
            GoghOptions {
                history_jobs: 0,
                seed: case as u64,
                ..Default::default()
            },
        )
        .unwrap();
        // several consecutive ticks: the delta must stay valid as the
        // placement evolves under the autoscaler's own actions
        for tick in 0..3 {
            let delta = sched.autoscale(&c);
            c.apply_delta(&delta).unwrap_or_else(|e| {
                panic!("case {case} tick {tick}: autoscale delta rejected: {e}")
            });
            for &j in &placed_before {
                assert!(
                    !c.placement.accels_of(j).is_empty(),
                    "case {case} tick {tick}: job {j} scaled to zero replicas"
                );
                let d = c.job(j).unwrap().distributability as usize;
                assert!(
                    c.placement.accels_of(j).len() <= d,
                    "case {case} tick {tick}: job {j} exceeds its replica cap"
                );
            }
            // no double-booking anywhere
            for &j in &placed_before {
                let mut seen = std::collections::HashSet::new();
                for aid in c.placement.accels_of(j) {
                    assert!(seen.insert(*aid), "job {j} double-booked on {aid}");
                }
            }
        }
    }
}

#[test]
fn prop_shards_partition_and_filter_availability() {
    use gogh::cluster::ClusterSpec as Spec;
    let mut rng = Rng::seed_from_u64(1414);
    for _case in 0..80 {
        let per_type = rng.range_u32_inclusive(1, 6);
        let spec = Spec::balanced(per_type);
        let p = rng.range_usize(1, 12);
        #[allow(deprecated)]
        let shards = spec.shards(p);
        assert_eq!(shards.len(), p.min(spec.len()));
        let mut seen: Vec<AccelId> = shards.iter().flat_map(|s| s.accels.clone()).collect();
        seen.sort();
        let mut all = spec.accels.clone();
        all.sort();
        assert_eq!(seen, all, "shards must cover each instance exactly once");
        // availability filtering never leaks a down instance into a pool
        let mut c = Cluster::new(spec);
        for _ in 0..rng.range_usize(0, 4) {
            let a = c.spec.accels[rng.range_usize(0, c.spec.accels.len())];
            c.set_accel_down(a);
        }
        for s in &shards {
            for a in c.shard_available_accels(s) {
                assert!(!c.is_accel_down(a));
                assert!(s.contains(a));
            }
        }
    }
}

#[test]
fn prop_topology_partitions_and_filters_availability() {
    use gogh::cluster::ClusterSpec as Spec;
    let mut rng = Rng::seed_from_u64(2828);
    for case in 0..80 {
        let per_type = rng.range_u32_inclusive(1, 6);
        let spec = Spec::balanced(per_type);
        let g = rng.range_usize(1, 8);
        let p = rng.range_usize(1, 6);
        let topo = spec.topology(g, p);
        // both levels clamp: no empty group or shard on a non-empty
        // cluster, and global shard indices stay sequential
        assert!(topo.groups.len() <= g.max(1));
        let indices: Vec<usize> = topo.shards().map(|(_, s, _)| s.index).collect();
        assert_eq!(indices, (0..topo.total_shards()).collect::<Vec<_>>(), "case {case}");
        for grp in &topo.groups {
            assert!(!grp.accels.is_empty(), "case {case}: empty group {}", grp.index);
            for s in &grp.shards {
                assert!(!s.accels.is_empty(), "case {case}: empty shard {}", s.index);
                for a in &s.accels {
                    assert!(grp.contains(*a), "case {case}: shard leaks outside its group");
                }
            }
        }
        // two-level partition: every instance in exactly one shard of
        // exactly one group
        let mut seen: Vec<AccelId> = topo.shards().flat_map(|(_, s, _)| s.accels.clone()).collect();
        seen.sort();
        let mut all = spec.accels.clone();
        all.sort();
        assert_eq!(seen, all, "case {case}: topology must cover each instance exactly once");
        // availability filtering never leaks a down instance into a pool
        let mut c = Cluster::new(spec);
        for _ in 0..rng.range_usize(0, 4) {
            let a = c.spec.accels[rng.range_usize(0, c.spec.accels.len())];
            c.set_accel_down(a);
        }
        for (_, s, set) in topo.shards() {
            for a in c.shard_available_accels(s) {
                assert!(!c.is_accel_down(a), "case {case}");
                assert!(set.contains(&a), "case {case}");
            }
        }
    }
}

#[test]
fn prop_builder_edit_sequences_match_from_scratch() {
    // Any sequence of job adds/removes and capacity churn applied to a
    // Problem1Builder must leave it building the exact constraint
    // matrix a cold `build_problem1` produces for the final state —
    // otherwise the incremental path drifts from the paper formulation.
    let mut rng = Rng::seed_from_u64(3131);
    for case in 0..30 {
        let oracle = ThroughputOracle::new(case);
        let universe: Vec<JobSpec> = (0..12u32)
            .map(|i| {
                let f = FAMILIES[i as usize % FAMILIES.len()];
                let b = f.batch_sizes()[i as usize % f.batch_sizes().len()];
                let mut j = JobSpec {
                    id: JobId(i),
                    family: f,
                    batch_size: b,
                    replication: 1,
                    min_throughput: 0.0,
                    distributability: 1 + i % 2,
                    work: 10.0,
                    priority: Default::default(),
                    elastic: false,
                    inference: None,
                };
                j.min_throughput = 0.3 * oracle.solo(&j, AccelType::P100);
                j
            })
            .collect();
        let jobs_c = universe.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / AccelType::V100.base_speed();

        let mut b = Problem1Builder::new(2);
        let mut live: BTreeMap<JobId, JobSpec> = BTreeMap::new();
        let mut counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 2)).collect();
        b.set_accel_counts(counts.clone());
        for _ in 0..rng.range_usize(3, 15) {
            match rng.range_usize(0, 4) {
                0 | 1 => {
                    // add (or re-add, which must replace cleanly)
                    let j = universe[rng.range_usize(0, universe.len())].clone();
                    live.insert(j.id, j.clone());
                    b.add_job(j, &thr);
                }
                2 => {
                    if !live.is_empty() {
                        let ids: Vec<JobId> = live.keys().copied().collect();
                        let id = ids[rng.range_usize(0, ids.len())];
                        live.remove(&id);
                        assert!(b.remove_job(id), "case {case}: live job missing from builder");
                    }
                }
                _ => {
                    let a = ACCEL_TYPES[rng.range_usize(0, ACCEL_TYPES.len())];
                    counts.insert(a, rng.range_u32_inclusive(0, 3));
                    b.set_accel_counts(counts.clone());
                }
            }
        }
        if live.is_empty() {
            let j = universe[0].clone();
            live.insert(j.id, j.clone());
            b.add_job(j, &thr);
        }
        let jobs_vec: Vec<JobSpec> = live.values().cloned().collect();
        assert_eq!(b.jobs_sorted(), jobs_vec, "case {case}: builder job list drifted");
        let input = Problem1Input {
            jobs: &jobs_vec,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &cap,
            max_pairs_per_job: 2,
            slack_penalty: Some(2000.0),
            throughput_bonus: 300.0,
            now_s: 0.0,
            power: Default::default(),
        };
        let (cold_model, cold_cols, cold_slacks) = build_problem1(&input, &BnbConfig::default());
        let (model, cols, slacks) = b.build(&input);
        assert_eq!(cols, cold_cols.as_slice(), "case {case}: column universe differs");
        assert_eq!(slacks, &cold_slacks, "case {case}: slack map differs");
        assert_eq!(model.obj_sense, cold_model.obj_sense);
        assert_eq!(model.vars.len(), cold_model.vars.len(), "case {case}");
        for (v, w) in model.vars.iter().zip(&cold_model.vars) {
            assert_eq!(v.name, w.name, "case {case}");
            assert_eq!((v.lb, v.ub, v.obj), (w.lb, w.ub, w.obj), "case {case}: {}", v.name);
            assert_eq!(v.kind, w.kind, "case {case}: {}", v.name);
        }
        assert_eq!(model.constraints.len(), cold_model.constraints.len(), "case {case}");
        for (x, y) in model.constraints.iter().zip(&cold_model.constraints) {
            assert_eq!(x.name, y.name, "case {case}");
            assert_eq!(x.terms, y.terms, "case {case}: {}", x.name);
            assert_eq!(x.sense, y.sense, "case {case}: {}", x.name);
            assert_eq!(x.rhs, y.rhs, "case {case}: {}", x.name);
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    use gogh::util::Json;
    let mut rng = Rng::seed_from_u64(707);
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 2 {
            rng.range_usize(0, 4)
        } else {
            rng.range_usize(0, 6)
        } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.range_f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(format!("s{}-\"q\"\n", rng.next_u32())),
            4 => Json::Array((0..rng.range_usize(0, 4)).map(|_| gen(rng, depth + 1)).collect()),
            _ => Json::Object(
                (0..rng.range_usize(0, 4))
                    .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    for _ in 0..200 {
        let v = gen(&mut rng, 0);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v, "roundtrip failed for {text}");
    }
}
