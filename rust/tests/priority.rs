//! Priority tiers, preemption, and elastic training, end to end
//! (ISSUE 9 acceptance): on the `priority` preset GOGH-with-preemption
//! strictly beats GOGH-without on Critical-tier SLO attainment and
//! beats the round-based Gavel baseline on tail finish-time fairness,
//! while priority-free runs never preempt and stay deterministic.

use gogh::baselines::GavelRoundsScheduler;
use gogh::cluster::ClusterSpec;
use gogh::config::ExperimentConfig;
use gogh::coordinator::{GoghOptions, GoghScheduler, SimDriver};
use gogh::engine::EngineOptions;
use gogh::metrics::RunReport;
use gogh::workload::{Priority, Trace, TraceEvent};

fn priority_cfg(n_jobs: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("priority").unwrap();
    cfg.trace.n_jobs = n_jobs;
    // keep the native bootstrap cheap in test budgets
    cfg.estimator.bootstrap_steps = 60;
    cfg
}

fn driver_for(cfg: &ExperimentConfig) -> SimDriver {
    let oracle = cfg.build_oracle().unwrap();
    let trace = Trace::generate(&cfg.trace, &oracle);
    SimDriver::new(
        ClusterSpec::mix(&cfg.cluster.accel_mix),
        oracle,
        trace,
        cfg.noise_sigma,
        cfg.monitor_interval_s,
        cfg.seed,
    )
    .unwrap()
    .with_options(EngineOptions::new().with_migration_cost(cfg.migration_cost_s))
}

fn run_gogh(cfg: &ExperimentConfig, preemption: bool) -> RunReport {
    let mut cfg = cfg.clone();
    cfg.gogh.preemption = preemption;
    let oracle = cfg.build_oracle().unwrap();
    let mut sched =
        GoghScheduler::with_native_backend(&oracle, GoghOptions::from_config(&cfg)).unwrap();
    driver_for(&cfg).run(&mut sched).unwrap()
}

fn run_gavel(cfg: &ExperimentConfig) -> RunReport {
    let oracle = cfg.build_oracle().unwrap();
    driver_for(cfg).run(&mut GavelRoundsScheduler::new(oracle)).unwrap()
}

#[test]
fn preemption_strictly_improves_critical_attainment_on_the_priority_preset() {
    let cfg = priority_cfg(60);
    let off = run_gogh(&cfg, false);
    let on = run_gogh(&cfg, true);
    let crit = Priority::Critical.index();
    assert_eq!(off.preemptions, 0, "preemption disabled but jobs were parked");
    assert!(on.preemptions > 0, "priority preset never exercised the preemption path");
    assert!(on.suspended_seconds > 0.0);
    assert!(
        on.tier_attainment[crit] > off.tier_attainment[crit],
        "critical attainment with preemption {:.4} does not beat without {:.4}",
        on.tier_attainment[crit],
        off.tier_attainment[crit]
    );
}

#[test]
fn gogh_beats_gavel_rounds_on_tail_finish_time_fairness() {
    let cfg = priority_cfg(60);
    let gogh = run_gogh(&cfg, true);
    let gavel = run_gavel(&cfg);
    assert_eq!(
        gavel.jobs_completed + gavel.jobs_cancelled,
        gavel.jobs_total,
        "gavel rounds failed to drain the trace"
    );
    assert!(gogh.ftf_p99 > 0.0 && gavel.ftf_p99 > 0.0, "no completed training jobs scored");
    assert!(
        gogh.ftf_p99 < gavel.ftf_p99,
        "gogh tail FTF {:.3} not better than gavel rounds {:.3}",
        gogh.ftf_p99,
        gavel.ftf_p99
    );
}

#[test]
fn priority_free_runs_never_preempt_and_tier_fields_stay_inert() {
    // The default preset predates priorities: every job is Standard and
    // rigid, so the new report fields must read as exactly "nothing
    // happened" — no preemptions, no parked seconds, vacuous 1.0
    // attainment for the empty best/critical tiers.
    let mut cfg = ExperimentConfig::default();
    cfg.trace.n_jobs = 30;
    cfg.estimator.bootstrap_steps = 60;
    let oracle = cfg.build_oracle().unwrap();
    let trace = Trace::generate(&cfg.trace, &oracle);
    for e in &trace.events {
        if let TraceEvent::Arrival { job, .. } = e {
            assert_eq!(job.priority, Priority::Standard);
            assert!(!job.elastic);
        }
    }
    let report = run_gogh(&cfg, false);
    assert_eq!(report.preemptions, 0);
    assert_eq!(report.suspended_seconds, 0.0);
    assert_eq!(report.tier_attainment[Priority::Best.index()], 1.0);
    assert_eq!(report.tier_attainment[Priority::Critical.index()], 1.0);
    // same config, same bytes out: the priority machinery must not
    // perturb the deterministic report of a priority-free run
    let again = run_gogh(&cfg, false);
    assert_eq!(report.row(), again.row(), "priority-free report drifted between runs");
}
