//! Inference-serving workload class, end to end: golden-trace
//! byte-stability of the training-only generator, the latency ILP
//! floor, deterministic replica-autoscaler behaviour, and the mixed
//! train+infer acceptance runs (GOGH-native completes the mixed preset
//! with serving SLOs met and beats the random baseline on attainment).

use gogh::baselines::RandomScheduler;
use gogh::cluster::{Cluster, ClusterSpec, PlacementOp};
use gogh::config::ExperimentConfig;
use gogh::coordinator::{GoghOptions, GoghScheduler, Scheduler, SimDriver};
use gogh::ilp::problem1::latency_adjusted_jobs;
use gogh::util::Rng;
use gogh::workload::{
    serving, AccelType, Combo, InferenceSpec, JobId, JobKind, JobSpec, ThroughputOracle, Trace,
    TraceConfig, TraceEvent, FAMILIES,
};

// ---------------------------------------------------------------------
// Golden-trace regression: the PR-2/3 arrival generator, reimplemented
// verbatim. Any change to the shared RNG draw order in Trace::generate
// (e.g. an inference field drawn from the wrong stream) breaks this.
// ---------------------------------------------------------------------

fn pr3_arrival_stream(cfg: &TraceConfig, oracle: &ThroughputOracle) -> Vec<(f64, JobSpec)> {
    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x7ace);
    let mut out = Vec::with_capacity(cfg.n_jobs);
    let mut t = 0.0f64;
    for i in 0..cfg.n_jobs {
        t += rng.exponential(cfg.mean_interarrival_s);
        let family = FAMILIES[rng.range_usize(0, FAMILIES.len())];
        let batches = family.batch_sizes();
        let batch = batches[rng.range_usize(0, batches.len())];
        let mut job = JobSpec {
            id: JobId(i as u32),
            family,
            batch_size: batch,
            replication: 1,
            min_throughput: 0.0,
            distributability: rng.range_u32_inclusive(1, cfg.max_distributability),
            work: rng.exponential(cfg.mean_work_s),
            priority: Default::default(),
            elastic: false,
            inference: None,
        };
        let p100 = oracle.solo(&job, AccelType::P100);
        job.min_throughput = cfg.slo_fraction * p100 * rng.range_f64(0.6, 1.0);
        out.push((t, job));
    }
    out
}

#[test]
fn training_only_traces_match_the_pr3_generator_byte_for_byte() {
    let configs = [
        TraceConfig::default(),
        TraceConfig {
            n_jobs: 250,
            mean_interarrival_s: 7.0,
            seed: 42,
            cancel_rate: 0.2,
            accel_churn: 3.0,
            ..Default::default()
        },
        TraceConfig {
            n_jobs: 120,
            max_distributability: 4,
            slo_fraction: 0.3,
            seed: 9,
            ..Default::default()
        },
    ];
    for cfg in configs {
        assert_eq!(cfg.inference_fraction, 0.0);
        let oracle = ThroughputOracle::new(cfg.seed);
        let golden = pr3_arrival_stream(&cfg, &oracle);
        let trace = Trace::generate(&cfg, &oracle);
        let arrivals: Vec<(f64, &JobSpec)> = trace
            .events
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Arrival { at, job } => Some((*at, job)),
                _ => None,
            })
            .collect();
        assert_eq!(arrivals.len(), golden.len());
        for ((gt, gj), (at, aj)) in golden.iter().zip(&arrivals) {
            assert!(gt.to_bits() == at.to_bits(), "arrival time drifted: {gt} vs {at}");
            assert_eq!(gj, *aj, "job spec drifted at {}", gj.id);
            assert_eq!(aj.kind(), JobKind::Training);
        }
    }
}

// ---------------------------------------------------------------------
// Latency ILP floor (constraint 2e′)
// ---------------------------------------------------------------------

#[test]
fn latency_adjustment_touches_only_inference_jobs() {
    let training = JobSpec {
        id: JobId(0),
        family: FAMILIES[0],
        batch_size: 32,
        replication: 1,
        min_throughput: 0.33,
        distributability: 2,
        work: 100.0,
        priority: Default::default(),
        elastic: false,
        inference: None,
    };
    let mut inference = training.clone();
    inference.id = JobId(1);
    inference.min_throughput = 0.0;
    inference.inference = Some(InferenceSpec {
        base_rate: 10.0,
        diurnal_amplitude: 0.2,
        diurnal_phase_s: 0.0,
        latency_slo_s: 0.25,
    });
    let adjusted = latency_adjusted_jobs(&[training.clone(), inference.clone()], 5_000.0);
    assert_eq!(adjusted[0], training, "training job must pass through untouched");
    let floor = adjusted[1].min_throughput;
    assert!(floor > 0.0, "inference job got no capacity floor");
    assert_eq!(
        floor,
        serving::effective_min_throughput(&inference, 5_000.0),
        "floor must come from the serving linearization"
    );
    // everything but the floor is preserved (id, replica cap, profile)
    assert_eq!(adjusted[1].inference, inference.inference);
    assert_eq!(adjusted[1].distributability, inference.distributability);
}

// ---------------------------------------------------------------------
// Deterministic autoscaler behaviour
// ---------------------------------------------------------------------

fn serving_job(id: u32, base_rate: f64, slo_s: f64, replica_cap: u32) -> JobSpec {
    JobSpec {
        id: JobId(id),
        family: FAMILIES[1],
        batch_size: 64,
        replication: 1,
        min_throughput: 0.0,
        distributability: replica_cap,
        work: 1000.0,
        priority: Default::default(),
        elastic: false,
        inference: Some(InferenceSpec {
            base_rate,
            diurnal_amplitude: 0.0,
            diurnal_phase_s: 0.0,
            latency_slo_s: slo_s,
        }),
    }
}

fn fresh_scheduler(seed: u64) -> GoghScheduler {
    let oracle = ThroughputOracle::new(seed);
    GoghScheduler::without_engine(
        &oracle,
        GoghOptions {
            history_jobs: 0,
            seed,
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn autoscaler_adds_a_replica_on_latency_breach() {
    // Cold catalog: a v100 replica is estimated at throughput 0.4 →
    // μ = 20 req/s. λ = 15 (17.25 with headroom) on ONE replica gives a
    // ~0.36 s M/M/1 sojourn; an SLO of 0.2 s breaches → scale up.
    let mut cluster = Cluster::new(ClusterSpec::mix(&[(AccelType::V100, 4)]));
    let job = serving_job(0, 15.0, 0.2, 3);
    cluster.add_job(job);
    cluster.placement.assign(cluster.spec.accels[0], Combo::Solo(JobId(0)));
    let mut sched = fresh_scheduler(1);
    let delta = sched.autoscale(&cluster);
    assert_eq!(delta.ops.len(), 1, "{:?}", delta.ops);
    assert!(
        matches!(delta.ops[0], PlacementOp::Assign { combo: Combo::Solo(JobId(0)), .. }),
        "{:?}",
        delta.ops[0]
    );
    cluster.apply_delta(&delta).unwrap();
    assert_eq!(cluster.placement.accels_of(JobId(0)).len(), 2);
    assert_eq!(Scheduler::autoscale_counts(&sched), (1, 0));
}

#[test]
fn autoscaler_releases_an_over_provisioned_replica() {
    // Three v100 replicas (μ = 60 req/s aggregate) serving λ = 0.5
    // against a 2 s SLO: dropping one still clears the hysteresis
    // margin comfortably → exactly one Evict.
    let mut cluster = Cluster::new(ClusterSpec::mix(&[(AccelType::V100, 4)]));
    let job = serving_job(0, 0.5, 2.0, 3);
    cluster.add_job(job);
    for i in 0..3 {
        cluster.placement.assign(cluster.spec.accels[i], Combo::Solo(JobId(0)));
    }
    let mut sched = fresh_scheduler(2);
    let delta = sched.autoscale(&cluster);
    assert_eq!(delta.ops.len(), 1, "{:?}", delta.ops);
    assert!(matches!(delta.ops[0], PlacementOp::Evict { .. }));
    cluster.apply_delta(&delta).unwrap();
    assert_eq!(cluster.placement.accels_of(JobId(0)).len(), 2);
    assert_eq!(Scheduler::autoscale_counts(&sched), (0, 1));
}

#[test]
fn autoscaler_never_scales_below_one_replica_or_breaks_pairs() {
    // One idle-ish replica: over-provisioned by any measure, but a solo
    // replica is the floor — no op may be emitted.
    let mut cluster = Cluster::new(ClusterSpec::mix(&[(AccelType::V100, 2)]));
    cluster.add_job(serving_job(0, 0.1, 5.0, 3));
    cluster.placement.assign(cluster.spec.accels[0], Combo::Solo(JobId(0)));
    let mut sched = fresh_scheduler(3);
    assert!(sched.autoscale(&cluster).is_empty());

    // Paired replicas are never broken: both replicas co-located with a
    // training job → no solo victim exists, even over-provisioned.
    let mut cluster = Cluster::new(ClusterSpec::mix(&[(AccelType::V100, 3)]));
    cluster.add_job(serving_job(0, 0.1, 5.0, 3));
    let mut t1 = serving_job(1, 0.0, 1.0, 1);
    t1.inference = None;
    let mut t2 = t1.clone();
    t2.id = JobId(2);
    cluster.add_job(t1);
    cluster.add_job(t2);
    cluster.placement.assign(cluster.spec.accels[0], Combo::pair(JobId(0), JobId(1)));
    cluster.placement.assign(cluster.spec.accels[1], Combo::pair(JobId(0), JobId(2)));
    let mut sched = fresh_scheduler(4);
    let delta = sched.autoscale(&cluster);
    assert!(
        !delta.ops.iter().any(|op| matches!(op, PlacementOp::Evict { .. })),
        "paired replica evicted: {:?}",
        delta.ops
    );
}

// ---------------------------------------------------------------------
// Mixed-preset acceptance runs
// ---------------------------------------------------------------------

fn mixed_cfg(n_jobs: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("mixed").unwrap();
    cfg.trace.n_jobs = n_jobs;
    // keep the native bootstrap cheap in test budgets
    cfg.estimator.bootstrap_steps = 60;
    cfg
}

fn run_random(cfg: &ExperimentConfig) -> gogh::metrics::RunReport {
    let oracle = cfg.build_oracle().unwrap();
    let trace = Trace::generate(&cfg.trace, &oracle);
    let mut driver = SimDriver::new(
        ClusterSpec::mix(&cfg.cluster.accel_mix),
        oracle,
        trace,
        cfg.noise_sigma,
        cfg.monitor_interval_s,
        cfg.seed,
    )
    .unwrap();
    driver.run(&mut RandomScheduler::new(cfg.seed)).unwrap()
}

fn run_gogh_native(cfg: &ExperimentConfig) -> (gogh::metrics::RunReport, GoghScheduler) {
    let oracle = cfg.build_oracle().unwrap();
    let trace = Trace::generate(&cfg.trace, &oracle);
    let mut driver = SimDriver::new(
        ClusterSpec::mix(&cfg.cluster.accel_mix),
        oracle.clone(),
        trace,
        cfg.noise_sigma,
        cfg.monitor_interval_s,
        cfg.seed,
    )
    .unwrap();
    let mut sched =
        GoghScheduler::with_native_backend(&oracle, GoghOptions::from_config(cfg)).unwrap();
    let report = driver.run(&mut sched).unwrap();
    (report, sched)
}

#[test]
fn gogh_native_serves_the_mixed_preset_within_slos() {
    let cfg = mixed_cfg(30);
    let (report, sched) = run_gogh_native(&cfg);
    assert!(report.inference_total > 0, "mixed preset produced no inference jobs");
    assert_eq!(
        report.jobs_completed + report.jobs_cancelled,
        report.jobs_total,
        "mixed run lost jobs"
    );
    assert!(
        report.inference_slo_met > 0,
        "no inference job met its latency SLO: attainment {:.3}, {} completed",
        report.inference_attainment,
        report.inference_completed
    );
    assert!(report.replica_seconds > 0.0);
    // serving measurements flowed into the learning loop
    let learn = sched.learning_stats();
    assert!(
        learn.inference_measurements > 0,
        "no inference measurement reached the catalog"
    );
}

#[test]
fn gogh_native_beats_random_on_inference_slo_attainment() {
    let cfg = mixed_cfg(40);
    let random = run_random(&cfg);
    let (gogh_report, _) = run_gogh_native(&cfg);
    assert!(gogh_report.inference_total > 0);
    assert_eq!(gogh_report.inference_total, random.inference_total);
    assert!(
        gogh_report.inference_attainment > random.inference_attainment,
        "gogh attainment {:.3} does not beat random {:.3}",
        gogh_report.inference_attainment,
        random.inference_attainment
    );
}
