//! Runtime end-to-end tests: the AOT artifact contract between
//! `python/compile` and the rust runtime — manifest ↔ encoding dims,
//! real training through PJRT reduces validation error, P2 refinement
//! beats the raw estimates it was given.
//!
//! All tests skip (with a notice) when `artifacts/` is absent.

use gogh::runtime::{DatasetBuilder, Engine, Estimator};
use gogh::workload::encoding;
use gogh::workload::ThroughputOracle;

fn engine() -> Option<std::sync::Arc<Engine>> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::load("artifacts").unwrap())
}

#[test]
fn manifest_dims_match_rust_encoding() {
    let Some(engine) = engine() else { return };
    let m = engine.manifest();
    assert_eq!(m.token_dim, 8);
    for arch in ["ff", "rnn", "transformer"] {
        let p1 = m.model(&format!("p1_{arch}")).unwrap();
        assert_eq!(p1.input_dim, encoding::P1_DIM);
        assert_eq!(p1.padded_dim, encoding::P1_DIM);
        assert_eq!(p1.out_dim, 2);
        let p2 = m.model(&format!("p2_{arch}")).unwrap();
        assert_eq!(p2.input_dim, encoding::P2_DIM);
        assert_eq!(p2.padded_dim, encoding::P2_PADDED);
        // n_params < n_state (Adam adds m, v, step)
        assert!(p2.n_params * 3 + 1 == p2.n_state(), "{arch}");
    }
}

#[test]
fn every_model_initializes_and_predicts_finite() {
    let Some(engine) = engine() else { return };
    for net in ["p1", "p2"] {
        for arch in ["ff", "rnn", "transformer"] {
            let key = format!("{net}_{arch}");
            let mut est = Estimator::new(&engine, &key).unwrap();
            let dim = est.spec().padded_dim;
            let rows = vec![vec![0.25f32; dim]; 3];
            let preds = est.predict(&rows).unwrap();
            assert_eq!(preds.len(), 3, "{key}");
            assert!(preds[0].iter().all(|v| v.is_finite()), "{key}");
        }
    }
}

#[test]
fn training_through_pjrt_reduces_validation_mae() {
    let Some(engine) = engine() else { return };
    let oracle = ThroughputOracle::new(3);
    let builder = DatasetBuilder::new(&oracle, 3);
    let split = builder.build_split("p1", 2000, 400);
    let mut est = Estimator::new(&engine, "p1_ff").unwrap();
    let xs: Vec<Vec<f32>> = split.val.iter().map(|s| s.x.clone()).collect();
    let ys: Vec<[f32; 2]> = split.val.iter().map(|s| s.y).collect();
    let (_, mae_before) = est.evaluate(&xs, &ys).unwrap();
    for (bx, by) in gogh::runtime::dataset::batches(&split.train, est.spec().train_batch, 1) {
        est.train_step(&bx, &by).unwrap();
    }
    let (_, mae_after) = est.evaluate(&xs, &ys).unwrap();
    assert!(
        mae_after < 0.6 * mae_before,
        "val MAE {mae_before} -> {mae_after}"
    );
    assert!(mae_after < 0.15, "val MAE too high after an epoch: {mae_after}");
}

#[test]
fn p2_refinement_beats_raw_estimates() {
    // Train P2 briefly, then verify its refined cross-GPU estimates have
    // lower MAE than the stale estimates it consumes — the Eq. 3 claim.
    let Some(engine) = engine() else { return };
    let oracle = ThroughputOracle::new(5);
    let builder = DatasetBuilder::new(&oracle, 5);
    let split = builder.build_split("p2", 6000, 600);
    let mut est = Estimator::new(&engine, "p2_ff").unwrap();
    // ~400 Adam steps (fig2b's budget) — undertrained P2 cannot beat
    // its stale inputs yet.
    for epoch in 0..18u64 {
        for (bx, by) in
            gogh::runtime::dataset::batches(&split.train, est.spec().train_batch, epoch)
        {
            est.train_step(&bx, &by).unwrap();
        }
    }
    let xs: Vec<Vec<f32>> = split.val.iter().map(|s| s.x.clone()).collect();
    let preds = est.predict(&xs).unwrap();
    let mut mae_refined = 0.0f64;
    let mut mae_stale = 0.0f64;
    for (s, p) in split.val.iter().zip(&preds) {
        // x[32] is the stale estimate of (a2, j1) — see encoding::p2_row
        mae_refined += (p[0] - s.y[0]).abs() as f64;
        mae_stale += (s.x[32] - s.y[0]).abs() as f64;
    }
    mae_refined /= split.val.len() as f64;
    mae_stale /= split.val.len() as f64;
    assert!(
        mae_refined < mae_stale,
        "P2 refined MAE {mae_refined} not better than stale {mae_stale}"
    );
}

#[test]
fn predict_is_pure_and_batch_invariant() {
    let Some(engine) = engine() else { return };
    let mut est = Estimator::new(&engine, "p1_transformer").unwrap();
    let mut rows = vec![];
    for i in 0..7 {
        rows.push(vec![0.1 * i as f32; 32]);
    }
    let a = est.predict(&rows).unwrap();
    let b = est.predict(&rows).unwrap();
    assert_eq!(a, b, "predict must not mutate state");
    // a subset must yield the same per-row outputs
    let c = est.predict(&rows[..3].to_vec()).unwrap();
    for i in 0..3 {
        assert_eq!(a[i], c[i]);
    }
}
