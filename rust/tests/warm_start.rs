//! Warm-started branch-and-bound: the greedy incumbent from
//! `baselines::greedy` must (a) be a valid feasible upper bound,
//! (b) never change the optimum the solver returns, (c) strictly shrink
//! the explored tree at scale, and (d) make node-budget cutoffs degrade
//! gracefully to the incumbent instead of failing.

use std::collections::BTreeMap;

use gogh::baselines::greedy_incumbent;
use gogh::ilp::branch_bound::{solve_ilp, BnbConfig, BnbStatus};
use gogh::ilp::problem1::{
    build_problem1, solve_problem1, solve_problem1_with_basis, ColumnBasis, Problem1Input,
};
use gogh::workload::{AccelType, Combo, JobId, JobSpec, ThroughputOracle, ACCEL_TYPES, FAMILIES};

fn mk_jobs(n: u32, oracle: &ThroughputOracle, slo_frac: f64) -> Vec<JobSpec> {
    (0..n)
        .map(|i| {
            let f = FAMILIES[i as usize % FAMILIES.len()];
            let b = f.batch_sizes()[i as usize % f.batch_sizes().len()];
            let mut j = JobSpec {
                id: JobId(i),
                family: f,
                batch_size: b,
                replication: 1,
                min_throughput: 0.0,
                distributability: 2,
                work: 100.0,
                priority: Default::default(),
                elastic: false,
                inference: None,
            };
            j.min_throughput = slo_frac * oracle.solo(&j, AccelType::P100);
            j
        })
        .collect()
}

/// Oracle-backed throughput closure over a fixed job set.
fn thr_fn(
    jobs: Vec<JobSpec>,
    oracle: ThroughputOracle,
) -> impl Fn(AccelType, JobId, &Combo) -> f64 {
    move |a: AccelType, j: JobId, c: &Combo| -> f64 {
        let spec = jobs.iter().find(|s| s.id == j).unwrap();
        let lookup = |id: JobId| jobs.iter().find(|s| s.id == id).cloned();
        oracle.throughput(spec, c, a, &lookup)
    }
}

fn solo_cap(a: AccelType) -> f64 {
    a.base_speed() / AccelType::V100.base_speed()
}

#[test]
fn greedy_incumbent_is_feasible_and_bounds_the_optimum() {
    for seed in 0..5u64 {
        let oracle = ThroughputOracle::new(seed);
        let jobs = mk_jobs(6, &oracle, 0.35);
        let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 2)).collect();
        let thr = thr_fn(jobs.clone(), oracle.clone());
        let input = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &solo_cap,
            max_pairs_per_job: 2,
            slack_penalty: Some(2000.0),
            throughput_bonus: 300.0,
            now_s: 0.0,
            power: Default::default(),
        };
        let cfg = BnbConfig::default();
        let (model, cols, slacks) = build_problem1(&input, &cfg);
        let x = greedy_incumbent(&input, &model, &cols, &slacks)
            .expect("soft-mode greedy must always produce an incumbent");
        assert!(model.is_feasible(&x, 1e-6), "seed {seed}: infeasible incumbent");
        let sol = solve_problem1(&input, &cfg);
        assert!(matches!(sol.status, BnbStatus::Optimal | BnbStatus::Feasible));
        // minimization: any feasible point is an upper bound on the optimum
        assert!(
            model.objective_value(&x) >= sol.objective - 1e-6,
            "seed {seed}: incumbent {} below optimum {}",
            model.objective_value(&x),
            sol.objective
        );
    }
}

#[test]
fn warm_and_cold_reach_identical_optima() {
    // Randomized small/mid instances where both runs prove optimality:
    // the warm start must never change the returned optimum, and over
    // the batch it must save nodes (strictly, in aggregate).
    let mut total_warm = 0usize;
    let mut total_cold = 0usize;
    for seed in 0..6u64 {
        let oracle = ThroughputOracle::new(seed * 7 + 1);
        let n = 4 + (seed % 2) as u32 * 2; // 4, 6, 4, 6, 4, 6
        let jobs = mk_jobs(n, &oracle, 0.4);
        let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 2)).collect();
        let thr = thr_fn(jobs.clone(), oracle.clone());
        let input = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &solo_cap,
            max_pairs_per_job: 2,
            slack_penalty: Some(2000.0),
            throughput_bonus: 300.0,
            now_s: 0.0,
            power: Default::default(),
        };
        let warm_cfg = BnbConfig {
            max_nodes: 100_000,
            time_limit_s: 60.0,
            ..Default::default()
        };
        let cold_cfg = BnbConfig {
            auto_warm_start: false,
            ..warm_cfg.clone()
        };
        let warm = solve_problem1(&input, &warm_cfg);
        let cold = solve_problem1(&input, &cold_cfg);
        assert!(warm.warm_started, "seed {seed}: greedy incumbent missing");
        assert!(!cold.warm_started);
        assert_eq!(warm.status, BnbStatus::Optimal, "seed {seed}");
        assert_eq!(cold.status, BnbStatus::Optimal, "seed {seed}");
        assert!(
            (warm.objective - cold.objective).abs() < 1e-6,
            "seed {seed}: warm {} vs cold {}",
            warm.objective,
            cold.objective
        );
        total_warm += warm.nodes;
        total_cold += cold.nodes;
    }
    // pruning can only remove work; the strict comparison lives in
    // warm_start_explores_strictly_fewer_nodes_at_scale
    assert!(
        total_warm <= total_cold,
        "warm start cost nodes: warm {total_warm} vs cold {total_cold}"
    );
}

#[test]
fn warm_start_explores_strictly_fewer_nodes_at_scale() {
    // The largest ilp_scaling-style configuration that still proves
    // optimality in test budgets. Cold start burns nodes discovering its
    // first incumbent; warm start prunes from node one.
    let mut total_warm = 0usize;
    let mut total_cold = 0usize;
    for seed in [41u64, 42, 43] {
        let oracle = ThroughputOracle::new(seed);
        let jobs = mk_jobs(10, &oracle, 0.35);
        let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 2)).collect();
        let thr = thr_fn(jobs.clone(), oracle.clone());
        let input = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &solo_cap,
            max_pairs_per_job: 2,
            slack_penalty: Some(2000.0),
            throughput_bonus: 300.0,
            now_s: 0.0,
            power: Default::default(),
        };
        let warm_cfg = BnbConfig {
            max_nodes: 150_000,
            time_limit_s: 120.0,
            ..Default::default()
        };
        let cold_cfg = BnbConfig {
            auto_warm_start: false,
            ..warm_cfg.clone()
        };
        let warm = solve_problem1(&input, &warm_cfg);
        let cold = solve_problem1(&input, &cold_cfg);
        // warm is never worse, and when both prove optimality the optima
        // are identical (the incumbent only prunes, never cuts the optimum)
        assert!(matches!(warm.status, BnbStatus::Optimal | BnbStatus::Feasible));
        assert!(
            warm.objective <= cold.objective + 1e-6,
            "seed {seed}: warm {} worse than cold {}",
            warm.objective,
            cold.objective
        );
        if warm.status == BnbStatus::Optimal && cold.status == BnbStatus::Optimal {
            assert!(
                (warm.objective - cold.objective).abs() < 1e-6,
                "seed {seed}: optima diverge"
            );
        }
        total_warm += warm.nodes;
        total_cold += cold.nodes;
    }
    assert!(
        total_warm < total_cold,
        "warm start must explore strictly fewer nodes: warm {total_warm} vs cold {total_cold}"
    );
}

#[test]
fn basis_warm_start_matches_cold_solve_at_ten_jobs() {
    // Simplex basis reuse (the arrival-chaining path) must be purely a
    // speed lever: at |J| = 10 the warm-started solve lands on the same
    // optimum as the cold one, and the exported basis round-trips
    // through a second solve unchanged.
    for seed in [51u64, 52, 53] {
        let oracle = ThroughputOracle::new(seed);
        let jobs = mk_jobs(10, &oracle, 0.35);
        let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 2)).collect();
        let thr = thr_fn(jobs.clone(), oracle.clone());
        let input = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &solo_cap,
            max_pairs_per_job: 2,
            slack_penalty: Some(2000.0),
            throughput_bonus: 300.0,
            now_s: 0.0,
            power: Default::default(),
        };
        let cfg = BnbConfig {
            max_nodes: 150_000,
            time_limit_s: 120.0,
            ..Default::default()
        };
        let cold = solve_problem1(&input, &cfg);
        assert_eq!(cold.status, BnbStatus::Optimal, "seed {seed}");
        assert!(cold.basis.is_none(), "cold solve must not export a basis");
        // empty hint = chaining enabled with no prior: crash fails
        // gracefully and the solve still proves the same optimum
        let first = solve_problem1_with_basis(&input, &cfg, &ColumnBasis::new());
        assert_eq!(first.status, BnbStatus::Optimal, "seed {seed}");
        assert!(
            (first.objective - cold.objective).abs() < 1e-6,
            "seed {seed}: basis path {} vs cold {}",
            first.objective,
            cold.objective
        );
        let basis = first.basis.clone().expect("chained solve exports its root basis");
        assert!(!basis.is_empty(), "seed {seed}: empty exported basis");
        // re-solve warm-started from the exported basis
        let warm = solve_problem1_with_basis(&input, &cfg, &basis);
        assert_eq!(warm.status, BnbStatus::Optimal, "seed {seed}");
        assert!(
            (warm.objective - cold.objective).abs() < 1e-6,
            "seed {seed}: warm {} vs cold {}",
            warm.objective,
            cold.objective
        );
    }
}

#[test]
fn node_budget_degrades_gracefully_to_the_incumbent() {
    let oracle = ThroughputOracle::new(9);
    let jobs = mk_jobs(8, &oracle, 0.4);
    let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 2)).collect();
    let thr = thr_fn(jobs.clone(), oracle.clone());
    let input = Problem1Input {
        jobs: &jobs,
        accel_counts: &counts,
        throughput: &thr,
        solo_capability: &solo_cap,
        max_pairs_per_job: 2,
        slack_penalty: Some(2000.0),
        throughput_bonus: 300.0,
        now_s: 0.0,
        power: Default::default(),
    };
    let cfg = BnbConfig::default();
    let (model, cols, slacks) = build_problem1(&input, &cfg);
    let incumbent = greedy_incumbent(&input, &model, &cols, &slacks).unwrap();
    let inc_obj = model.objective_value(&incumbent);

    // max_nodes = 0: the search may not expand anything — it must come
    // back with exactly the warm-start incumbent, not an error.
    let strangled = BnbConfig {
        max_nodes: 0,
        warm_start: Some(incumbent.clone()),
        ..Default::default()
    };
    let r = solve_ilp(&model, &strangled);
    assert!(r.warm_started);
    assert!(matches!(r.status, BnbStatus::Optimal | BnbStatus::Feasible), "{:?}", r.status);
    assert_eq!(r.x, incumbent);
    assert!((r.objective - inc_obj).abs() < 1e-9);
    // an Optimal claim from a strangled search must be backed by a
    // genuinely closed gap, never by a discarded frontier
    if r.status == BnbStatus::Optimal {
        assert!(r.gap() < 1e-6, "optimal without a closed gap: {}", r.gap());
    }

    // and the budget is monotone: more nodes never worsen the objective
    let generous = solve_ilp(
        &model,
        &BnbConfig {
            warm_start: Some(incumbent.clone()),
            ..Default::default()
        },
    );
    assert!(generous.objective <= r.objective + 1e-9);
}
