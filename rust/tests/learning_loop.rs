//! The native-backend learning loop, end to end and artifact-free:
//! P1 priors → deployment → monitoring → P2 refinement → online Adam
//! steps — the paper's core iterative claim, gated in CI.
//!
//! The headline test is `refinement_convergence_beats_cold_prior`: on a
//! seeded trace of measurements, the P2 MAE on held-out (job, accel)
//! pairs must strictly improve over the cold prior after N refinement
//! rounds. Everything here is deterministic from its seeds (pure-Rust
//! math, no threads on the learning path).

use gogh::catalog::{Catalog, EstimateKey};
use gogh::cluster::{AccelId, Measurement};
use gogh::config::{BackendKind, ExperimentConfig};
use gogh::coordinator::{history, refinement, Gogh};
use gogh::runtime::dataset::batches;
use gogh::runtime::{Backend, NativeBackend, Sample};
use gogh::workload::trace::table2_universe;
use gogh::workload::{AccelType, Combo, JobId, JobSpec, ThroughputOracle, ACCEL_TYPES};

const SEED: u64 = 4242;
/// The one accelerator type the "cluster" observes measurements on.
const OBSERVED: AccelType = AccelType::K80;
/// Monitoring rounds of the convergence scenario.
const ROUNDS: u32 = 8;

/// MAE of the catalog's current estimates vs ground truth over the
/// held-out pairs: every eval job × every accel type that was never
/// measured (only refined toward).
fn held_out_mae(catalog: &Catalog, oracle: &ThroughputOracle, jobs: &[JobSpec]) -> f64 {
    let mut abs = 0.0f64;
    let mut n = 0usize;
    for j in jobs {
        for &a in ACCEL_TYPES.iter().filter(|&&a| a != OBSERVED) {
            let est = refinement::catalog_value(catalog, a, j.id, &Combo::Solo(j.id));
            abs += (est - oracle.solo(j, a)).abs();
            n += 1;
        }
    }
    abs / n as f64
}

/// Fresh (never-estimated) jobs drawn across the Table 2 universe.
fn eval_jobs() -> Vec<JobSpec> {
    table2_universe()
        .iter()
        .step_by(3)
        .take(8)
        .enumerate()
        .map(|(i, &(family, batch_size))| JobSpec {
            id: JobId(500 + i as u32),
            family,
            batch_size,
            replication: 1,
            min_throughput: 0.0,
            distributability: 1,
            work: 1.0,
            priority: Default::default(),
            elastic: false,
            inference: None,
        })
        .collect()
}

#[test]
fn refinement_convergence_beats_cold_prior() {
    let oracle = ThroughputOracle::new(SEED);
    let mut catalog = Catalog::new();
    history::seed_catalog(&mut catalog, &oracle, 20, 0.02, SEED);

    // Bootstrap-train the native P2 from catalog history alone, over a
    // spread of stale-estimate noise levels: at sigma 0.8 the estimate
    // features are nearly useless, which teaches the network to lean on
    // the fresh a1 measurement + Ψ — exactly the regime the cold-start
    // queries put it in (their estimate slots hold priors, not truths).
    let mut p2 = NativeBackend::p2(SEED);
    let mut train: Vec<Sample> = vec![];
    for (salt, sigma) in [(1u64, 0.15f64), (2, 0.4), (3, 0.8)] {
        train.extend(history::p2_samples_from_catalog(&catalog, 3000, sigma, SEED ^ salt));
    }
    assert!(train.len() > 4000, "bootstrap set too small: {}", train.len());
    let mut steps = 0;
    'outer: for epoch in 0..100u64 {
        for (xs, ys) in batches(&train, p2.train_batch(), SEED ^ epoch) {
            p2.train_step(&xs, &ys).unwrap();
            steps += 1;
            if steps >= 600 {
                break 'outer;
            }
        }
    }
    assert_eq!(p2.steps_taken(), 600);

    let jobs = eval_jobs();
    for j in &jobs {
        catalog.register_job(j.id, j.psi());
    }
    let cold = held_out_mae(&catalog, &oracle, &jobs);

    // N monitoring rounds: measure every eval job on the observed type
    // (coordinator order: record first, then refine), letting P2 carry
    // the observation to the 5 unobserved types (Eq. 3/4).
    let aid = AccelId {
        server: 0,
        accel: OBSERVED,
    };
    for round in 1..=ROUNDS {
        let measurements: Vec<Measurement> = jobs
            .iter()
            .map(|j| Measurement {
                job: j.id,
                combo: Combo::Solo(j.id),
                accel: aid,
                throughput: oracle.solo(j, OBSERVED),
                at: round as f64 * 30.0,
            })
            .collect();
        for m in &measurements {
            catalog.record_measurement(
                EstimateKey {
                    accel: OBSERVED,
                    job: m.job,
                    combo: m.combo,
                },
                m.throughput,
            );
        }
        let applied =
            refinement::refine_round(&mut catalog, &mut p2, &measurements, round).unwrap();
        assert_eq!(applied, jobs.len() * (ACCEL_TYPES.len() - 1));
    }

    let post = held_out_mae(&catalog, &oracle, &jobs);
    assert!(
        post < cold,
        "P2 refinement must strictly improve held-out MAE: cold {cold:.4} -> post {post:.4}"
    );
}

fn native_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.gogh.backend = BackendKind::Native;
    cfg.trace.n_jobs = 8;
    cfg.trace.mean_interarrival_s = 25.0;
    cfg.trace.mean_work_s = 120.0;
    cfg.trace.seed = seed;
    cfg.seed = seed;
    cfg.monitor_interval_s = 20.0;
    cfg.estimator.bootstrap_steps = 60;
    cfg
}

#[test]
fn native_backend_runs_the_full_learning_loop() {
    let mut sys = Gogh::from_config(&native_cfg(33)).unwrap();
    assert_eq!(sys.backend_name(), "native");
    let report = sys.run().unwrap();
    assert_eq!(report.jobs_completed, 8, "native gogh lost jobs");
    // the loop actually learned: P2 refined, both networks trained —
    // and specifically took ONLINE steps after bootstrap (a dead
    // monitor path can't hide behind construction-time training)
    let learn = sys.scheduler().learning_stats();
    assert!(learn.refinement_rounds > 0, "no P2 refinement round ran");
    assert!(learn.p1_train_steps > 0, "P1 never trained");
    assert!(learn.p2_train_steps > 0, "P2 never trained");
    assert!(learn.p1_online_steps > 0, "P1 took no online steps");
    assert!(learn.p2_online_steps > 0, "P2 took no online steps");
    assert!(learn.p1_train_steps > learn.p1_online_steps, "bootstrap steps missing");
    // estimates were scored against real measurements
    let mae = report.estimation_mae.expect("estimation MAE tracked");
    assert!(mae.is_finite() && mae >= 0.0);
    assert!(report.mean_p1_ms > 0.0, "P1 inference latency untracked");
}

#[test]
fn native_runs_are_bit_reproducible() {
    let run = || {
        let mut sys = Gogh::from_config(&native_cfg(37)).unwrap();
        let r = sys.run().unwrap();
        let learn = sys.scheduler().learning_stats();
        (
            r.energy_joules,
            r.mean_jct,
            r.slo_deficit,
            r.migrations,
            learn.p1_train_steps,
            learn.p2_train_steps,
            learn.refinement_rounds,
        )
    };
    assert_eq!(run(), run(), "seeded native runs diverged");
}

#[test]
fn auto_backend_falls_back_to_native_without_artifacts() {
    let mut cfg = native_cfg(35);
    cfg.gogh.backend = BackendKind::Auto;
    cfg.estimator.artifacts_dir = "no/such/artifacts".to_string();
    let sys = Gogh::from_config(&cfg).unwrap();
    assert_eq!(sys.backend_name(), "native");
}

#[test]
fn explicit_pjrt_without_artifacts_is_a_clear_one_line_error() {
    let mut cfg = native_cfg(36);
    cfg.gogh.backend = BackendKind::Pjrt;
    cfg.estimator.artifacts_dir = "no/such/artifacts".to_string();
    let err = match Gogh::from_config(&cfg) {
        Err(e) => e,
        Ok(_) => panic!("pjrt without artifacts must be a hard error"),
    };
    let msg = format!("{err}");
    assert!(msg.contains("pjrt"), "error should name the backend: {msg}");
    assert!(
        msg.contains("--backend native"),
        "error should point at the native escape hatch: {msg}"
    );
    assert!(!msg.contains('\n'), "error must be one line: {msg:?}");
}

#[test]
fn none_backend_stays_estimator_free() {
    let mut cfg = native_cfg(38);
    cfg.gogh.backend = BackendKind::None;
    let mut sys = Gogh::from_config(&cfg).unwrap();
    assert_eq!(sys.backend_name(), "none");
    let report = sys.run().unwrap();
    assert_eq!(report.jobs_completed, 8);
    let learn = sys.scheduler().learning_stats();
    assert_eq!(learn.refinement_rounds, 0);
    assert_eq!(learn.p1_train_steps + learn.p2_train_steps, 0);
}
