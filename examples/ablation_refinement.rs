//! Ablation: how much does the P2 refinement loop (Eq. 3/4) matter?
//!
//! Runs the same trace three ways —
//!   * full GOGH (P1 + P2 refinement + online learning),
//!   * P1-only (refinement disabled),
//!   * frozen (refinement on, online learning off)
//! — and reports estimation MAE + energy. The refinement loop is the
//! paper's core claim: observing one GPU type should sharpen estimates
//! on all the others.
//!
//!     cargo run --release --example ablation_refinement

use gogh::cluster::ClusterSpec;
use gogh::config::ExperimentConfig;
use gogh::coordinator::{GoghOptions, GoghScheduler, SimDriver};
use gogh::runtime::Engine;
use gogh::workload::{ThroughputOracle, Trace};

fn main() -> gogh::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.trace.n_jobs = 30;
    cfg.trace.mean_interarrival_s = 40.0;
    cfg.trace.mean_work_s = 800.0;
    cfg.seed = 31;
    cfg.trace.seed = 31;
    let engine = Engine::load(&cfg.estimator.artifacts_dir)?;

    println!(
        "{:<22} {:>10} {:>10} {:>9} {:>7}",
        "variant", "est_mae", "busy_J", "slo_def", "viols"
    );
    for (name, refine, online) in [
        ("gogh-full", true, cfg.estimator.online_steps_per_round),
        ("gogh-p1-only", false, cfg.estimator.online_steps_per_round),
        ("gogh-frozen", true, 0),
        ("gogh-p1-only-frozen", false, 0),
    ] {
        let oracle = ThroughputOracle::new(cfg.seed);
        let trace = Trace::generate(&cfg.trace, &oracle);
        let mut driver = SimDriver::new(
            ClusterSpec::mix(&cfg.cluster.accel_mix),
            oracle.clone(),
            trace,
            cfg.noise_sigma,
            cfg.monitor_interval_s,
            cfg.seed,
        )?;
        let mut est_cfg = cfg.estimator.clone();
        est_cfg.online_steps_per_round = online;
        let mut sched = GoghScheduler::new(
            &engine,
            &oracle,
            GoghOptions {
                estimator: est_cfg,
                optimizer: cfg.optimizer.clone(),
                enable_refinement: refine,
                seed: cfg.seed,
                ..Default::default()
            },
        )?;
        let report = driver.run(&mut sched)?;
        println!(
            "{:<22} {:>10.4} {:>10.0} {:>9.3} {:>7}",
            name,
            report.estimation_mae.unwrap_or(f64::NAN),
            report.energy_joules,
            report.slo_deficit,
            report.slo_violations
        );
    }
    println!("\nlower est_mae with refinement on == the paper's Eq. 3/4 claim");
    Ok(())
}
