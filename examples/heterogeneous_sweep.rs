//! Heterogeneity sweep: how the schedulers compare as the cluster mix
//! shifts from legacy-heavy (mostly k80) to modern-heavy (mostly v100)
//! — the scenario the paper's introduction motivates (mixed-generation
//! clusters that cannot be upgraded wholesale).
//!
//!     cargo run --release --example heterogeneous_sweep

use gogh::baselines::{GreedyScheduler, RandomScheduler};
use gogh::cluster::ClusterSpec;
use gogh::config::ExperimentConfig;
use gogh::coordinator::{GoghOptions, GoghScheduler, SimDriver};
use gogh::runtime::Engine;
use gogh::workload::{AccelType, ThroughputOracle, Trace};

fn mixes() -> Vec<(&'static str, Vec<(AccelType, u32)>)> {
    use AccelType::*;
    vec![
        (
            "legacy-heavy",
            vec![(K80, 5), (K80Unconsolidated, 3), (P100, 2), (V100, 1)],
        ),
        (
            "balanced",
            vec![
                (K80, 2),
                (K80Unconsolidated, 2),
                (P100, 2),
                (P100Unconsolidated, 2),
                (V100, 2),
                (V100Unconsolidated, 2),
            ],
        ),
        (
            "modern-heavy",
            vec![(V100, 5), (V100Unconsolidated, 3), (P100, 2), (K80, 1)],
        ),
    ]
}

fn main() -> gogh::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.trace.n_jobs = 24;
    cfg.trace.mean_interarrival_s = 50.0;
    cfg.trace.mean_work_s = 700.0;
    cfg.seed = 23;
    cfg.trace.seed = 23;
    let engine = Engine::load(&cfg.estimator.artifacts_dir)?;

    println!(
        "{:<14} {:<10} {:>12} {:>10} {:>8} {:>8}",
        "mix", "policy", "busy_J", "J/job", "slo_def", "jct_s"
    );
    for (mix_name, mix) in mixes() {
        for policy in ["random", "greedy", "gogh"] {
            let oracle = ThroughputOracle::new(cfg.seed);
            let trace = Trace::generate(&cfg.trace, &oracle);
            let mut driver = SimDriver::new(
                ClusterSpec::mix(&mix),
                oracle.clone(),
                trace,
                cfg.noise_sigma,
                cfg.monitor_interval_s,
                cfg.seed,
            )?;
            let report = match policy {
                "random" => driver.run(&mut RandomScheduler::new(cfg.seed))?,
                "greedy" => driver.run(&mut GreedyScheduler::new())?,
                _ => {
                    let mut sched = GoghScheduler::new(
                        &engine,
                        &oracle,
                        GoghOptions {
                            estimator: cfg.estimator.clone(),
                            optimizer: cfg.optimizer.clone(),
                            seed: cfg.seed,
                            ..Default::default()
                        },
                    )?;
                    driver.run(&mut sched)?
                }
            };
            println!(
                "{:<14} {:<10} {:>12.0} {:>10.0} {:>8.3} {:>8.1}",
                mix_name,
                policy,
                report.energy_joules,
                report.joules_per_job(),
                report.slo_deficit,
                report.mean_jct
            );
        }
    }
    Ok(())
}
