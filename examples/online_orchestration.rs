//! End-to-end driver (DESIGN.md §E2E): run the full GOGH system on a
//! realistic online trace — P1 initial estimation, ILP allocation,
//! monitoring, P2 cross-GPU refinement and continuous online training of
//! both AOT-compiled networks — and compare against every baseline on
//! the same trace. Logs the online-learning loss curve of the estimator
//! pair along the way.
//!
//!     cargo run --release --example online_orchestration
//!
//! The headline numbers of EXPERIMENTS.md §E2E come from this binary.

use gogh::baselines::{GreedyScheduler, OracleScheduler, RandomScheduler};
use gogh::cluster::ClusterSpec;
use gogh::config::ExperimentConfig;
use gogh::coordinator::history;
use gogh::coordinator::{GoghOptions, GoghScheduler, SimDriver};
use gogh::metrics::SchedulerComparison;
use gogh::runtime::{Engine, Estimator};
use gogh::workload::{ThroughputOracle, Trace};

fn main() -> gogh::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.trace.n_jobs = 40;
    cfg.trace.mean_interarrival_s = 40.0;
    cfg.trace.mean_work_s = 900.0;
    cfg.seed = 11;
    cfg.trace.seed = 11;

    let engine = Engine::load(&cfg.estimator.artifacts_dir)?;

    // ---- phase 1: online-learning curve of the estimator pair --------
    // Train P1 (RNN) on catalog history exactly as the coordinator's
    // bootstrap does, logging the loss curve (a few hundred steps).
    println!("== online estimator training (P1 = rnn) ==");
    let oracle = ThroughputOracle::new(cfg.seed);
    let mut catalog = gogh::catalog::Catalog::new();
    history::seed_catalog(&mut catalog, &oracle, 24, 0.02, cfg.seed);
    let samples = history::p1_samples_from_catalog(&catalog, 4096, cfg.seed);
    let mut p1 = Estimator::new(&engine, "p1_rnn")?;
    let mut rng = gogh::util::Rng::seed_from_u64(cfg.seed);
    let batch = p1.spec().train_batch;
    for step in 0..300 {
        let mut idx: Vec<usize> = (0..samples.len()).collect();
        rng.shuffle(&mut idx);
        let xs: Vec<Vec<f32>> = idx[..batch.min(samples.len())]
            .iter()
            .map(|&i| samples[i].x.clone())
            .collect();
        let ys: Vec<[f32; 2]> = idx[..batch.min(samples.len())]
            .iter()
            .map(|&i| samples[i].y)
            .collect();
        let (loss, mae) = p1.train_step(&xs, &ys)?;
        if step % 30 == 0 || step == 299 {
            println!("  step {step:>4}  loss {loss:.5}  mae {mae:.4}");
        }
    }

    // ---- phase 2: full system comparison on one trace ----------------
    println!("\n== scheduler comparison ({} jobs) ==", cfg.trace.n_jobs);
    let mut table = SchedulerComparison::default();
    for policy in ["random", "greedy", "gogh", "gogh-frozen", "oracle-ilp"] {
        let oracle = ThroughputOracle::new(cfg.seed);
        let trace = Trace::generate(&cfg.trace, &oracle);
        let spec = ClusterSpec::mix(&cfg.cluster.accel_mix);
        let mut driver = SimDriver::new(
            spec,
            oracle.clone(),
            trace,
            cfg.noise_sigma,
            cfg.monitor_interval_s,
            cfg.seed,
        )?;
        let report = match policy {
            "random" => driver.run(&mut RandomScheduler::new(cfg.seed))?,
            "greedy" => driver.run(&mut GreedyScheduler::new())?,
            "oracle-ilp" => {
                driver.run(&mut OracleScheduler::new(oracle, cfg.optimizer.clone()))?
            }
            name => {
                let mut opts = GoghOptions {
                    estimator: cfg.estimator.clone(),
                    optimizer: cfg.optimizer.clone(),
                    seed: cfg.seed,
                    ..Default::default()
                };
                if name == "gogh-frozen" {
                    // ablation: no online learning after bootstrap
                    opts.estimator.online_steps_per_round = 0;
                }
                let mut sched = GoghScheduler::new(&engine, &oracle, opts)?;
                let mut rep = driver.run(&mut sched)?;
                rep.scheduler = name.to_string();
                rep
            }
        };
        println!("  finished {policy}");
        table.push(report);
    }
    println!("\n{}", table.table());
    println!("energy vs random baseline:");
    for (name, ratio) in table.energy_ratios() {
        println!("  {name:<14} {ratio:.3}x");
    }
    Ok(())
}
