//! Quickstart: bring up the full GOGH stack on a small heterogeneous
//! cluster, schedule a short trace, and print the run report.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` (the AOT-compiled estimators).

use gogh::config::ExperimentConfig;
use gogh::coordinator::Gogh;
use gogh::metrics::RunReport;

fn main() -> gogh::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.trace.n_jobs = 12;
    cfg.trace.mean_interarrival_s = 45.0;
    cfg.trace.mean_work_s = 600.0;
    cfg.seed = 7;
    cfg.trace.seed = 7;

    println!("cluster:");
    for (a, n) in &cfg.cluster.accel_mix {
        println!("  {:<22} x{}", a.name(), n);
    }
    println!(
        "\nscheduling {} jobs with P1={} / P2={} ...\n",
        cfg.trace.n_jobs, cfg.estimator.p1_arch, cfg.estimator.p2_arch
    );

    let mut sys = Gogh::from_config(&cfg)?;
    let report = sys.run()?;

    println!("{}", RunReport::header());
    println!("{}", report.row());
    println!(
        "\nenergy per completed job: {:.0} J",
        report.joules_per_job()
    );
    if let Some(mae) = report.estimation_mae {
        println!("throughput-estimation MAE: {mae:.4} (normalized units)");
    }
    println!(
        "decision path: ILP {:.2} ms, P1 {:.2} ms per call",
        report.mean_solve_ms, report.mean_p1_ms
    );
    println!(
        "catalog: {} records ({} measured)",
        sys.scheduler().catalog.len(),
        sys.scheduler().catalog.n_measured()
    );
    Ok(())
}
