"""Unit tests for the CI bench regression gate (.github/scripts/bench_gate.py).

Stdlib + pytest only — these run in the advisory python job and keep the
gate script itself from rotting (it fails builds, so it must be right).
"""

import importlib.util
import json
import pathlib
import subprocess
import sys

SCRIPT = pathlib.Path(__file__).resolve().parents[2] / ".github" / "scripts" / "bench_gate.py"

spec = importlib.util.spec_from_file_location("bench_gate", SCRIPT)
bench_gate = importlib.util.module_from_spec(spec)
spec.loader.exec_module(bench_gate)


def record(**overrides):
    base = {
        "bench": "e2e_scheduling",
        "jobs": 300,
        "mean_decision_ms": 10.0,
        "p99_decision_ms": 40.0,
        "explored_nodes": 1000,
        "peak_rss_bytes": 100_000_000,
    }
    base.update(overrides)
    return base


def test_within_budget_passes():
    assert bench_gate.gate(record(), record(), 0.25) == 0


def test_improvement_passes():
    measured = record(mean_decision_ms=4.0, explored_nodes=500, peak_rss_bytes=50_000_000)
    assert bench_gate.gate(measured, record(), 0.25) == 0


def test_latency_regression_fails():
    assert bench_gate.gate(record(mean_decision_ms=13.0), record(), 0.25) == 1


def test_node_regression_fails():
    assert bench_gate.gate(record(explored_nodes=2000), record(), 0.25) == 1


def test_p99_regression_fails():
    # a fat decision tail must fail even when the mean stays healthy
    assert bench_gate.gate(record(p99_decision_ms=80.0), record(), 0.25) == 1


def test_p99_vanishing_from_the_record_is_malformed():
    measured = record()
    del measured["p99_decision_ms"]
    assert bench_gate.gate(measured, record(), 0.25) == 2
    # pre-extension baselines never gated the tail — skipping is fine
    old_baseline = {"bench": "e2e_scheduling", "jobs": 300, "mean_decision_ms": 10.0}
    assert bench_gate.gate(measured, old_baseline, 0.25) == 0


def test_rss_regression_fails():
    assert bench_gate.gate(record(peak_rss_bytes=300_000_000), record(), 0.25) == 1


def test_rss_unmeasurable_is_skipped():
    # peak_rss_bytes == 0 means "no procfs", never "tiny"
    assert bench_gate.gate(record(peak_rss_bytes=0), record(), 0.25) == 0


def test_missing_required_field_is_malformed():
    measured = record()
    del measured["mean_decision_ms"]
    assert bench_gate.gate(measured, record(), 0.25) == 2


def test_broken_baseline_cannot_silently_disable_the_gate():
    # a baseline typo or a zeroed value must fail loudly, never skip
    baseline = record()
    del baseline["mean_decision_ms"]
    assert bench_gate.gate(record(), baseline, 0.25) == 2
    assert bench_gate.gate(record(), record(mean_decision_ms=0.0), 0.25) == 2
    # optional fields with broken baselines still just skip
    assert bench_gate.gate(record(), record(explored_nodes=0), 0.25) == 0


def test_pre_extension_baselines_skip_the_new_fields():
    # baselines predating the extended gate carry only the latency field
    old_baseline = {"bench": "e2e_scheduling", "jobs": 300, "mean_decision_ms": 10.0}
    assert bench_gate.gate(record(), old_baseline, 0.25) == 0


def test_gated_field_vanishing_from_the_record_is_malformed():
    # the measured record is freshly emitted by HEAD: a gated field
    # disappearing while the baseline still carries one means a refactor
    # silently disabled that gate — must fail, not skip
    measured = record()
    del measured["explored_nodes"]
    assert bench_gate.gate(measured, record(), 0.25) == 2
    # ...but if the baseline never gated it either, skipping is fine
    old_baseline = {"bench": "e2e_scheduling", "jobs": 300, "mean_decision_ms": 10.0}
    assert bench_gate.gate(measured, old_baseline, 0.25) == 0


def test_bench_name_mismatch_is_malformed():
    assert bench_gate.gate(record(bench="other"), record(), 0.25) == 2


def test_non_numeric_field_is_malformed():
    assert bench_gate.gate(record(mean_decision_ms="fast"), record(), 0.25) == 2


def test_exact_limit_is_not_a_regression():
    assert bench_gate.gate(record(mean_decision_ms=12.5), record(), 0.25) == 0


def test_cli_end_to_end(tmp_path):
    measured = tmp_path / "measured.json"
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps(record()))

    measured.write_text(json.dumps(record(mean_decision_ms=9.0)))
    ok = subprocess.run(
        [sys.executable, str(SCRIPT), str(measured), str(baseline), "0.25"],
        capture_output=True,
        text=True,
    )
    assert ok.returncode == 0, ok.stdout + ok.stderr

    measured.write_text(json.dumps(record(mean_decision_ms=99.0)))
    bad = subprocess.run(
        [sys.executable, str(SCRIPT), str(measured), str(baseline), "0.25"],
        capture_output=True,
        text=True,
    )
    assert bad.returncode == 1, bad.stdout + bad.stderr
    assert "FAIL" in bad.stdout
