"""pytest: AOT lowering round-trip — HLO text artifacts + manifest.

Lowers one representative model end-to-end through ``aot.lower_model``
and validates the artifact contract the rust runtime assumes: HLO text
parses (non-empty, ENTRY present), manifest shapes match
``model.state_entries``, and the fixed batch dims are recorded.
"""

import json
import pathlib

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entry = aot.lower_model("p1", "ff", out, lr=1e-3)
    return out, entry


def test_hlo_files_exist_and_parse_shape(lowered):
    out, entry = lowered
    for kind in ("init", "fwd", "train"):
        text = (out / entry["files"][kind]).read_text()
        assert "ENTRY" in text and "HloModule" in text
        assert len(text) > 1000


def test_manifest_entry_matches_model(lowered):
    _, entry = lowered
    entries = model.state_entries("p1", "ff")
    assert [e["name"] for e in entry["state"]] == [n for n, _ in entries]
    assert [tuple(e["shape"]) for e in entry["state"]] == [s for _, s in entries]
    assert entry["input_dim"] == 32
    assert entry["padded_dim"] == 32
    assert entry["train_batch"] == aot.TRAIN_BATCH
    assert entry["param_count"] == model.param_count(model.init_params("p1", "ff"))


def test_train_hlo_io_arity(lowered):
    """train HLO: |state| + 2 inputs (state, x, y) in the ENTRY block."""
    out, entry = lowered
    text = (out / entry["files"]["train"]).read_text()
    n_state = len(entry["state"])
    # count parameter(...) instructions inside the ENTRY computation
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    n_inputs = 0
    for l in lines[start + 1 :]:
        if l.startswith("}"):
            break
        if " parameter(" in l:
            n_inputs += 1
    assert n_inputs == n_state + 2, (n_inputs, n_state)
