"""pytest: L2 estimator models — shapes, determinism, training descent,
and the flat-state contract the rust runtime depends on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

ALL = [(net, arch) for net in model.NETS for arch in model.ARCHS]


def _batch(net, n=32, seed=0):
    _, pad, _ = model.NETS[net]
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (n, pad))
    # targets in [0,1] like normalized throughputs
    y = jax.random.uniform(ky, (n, model.OUT_DIM))
    return x, y


@pytest.mark.parametrize("net,arch", ALL)
def test_forward_shape(net, arch):
    params = model.init_params(net, arch)
    x, _ = _batch(net)
    out = model.apply(params, x, arch)
    assert out.shape == (32, model.OUT_DIM)
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("net,arch", ALL)
def test_init_deterministic(net, arch):
    a = model.init_params(net, arch, seed=7)
    b = model.init_params(net, arch, seed=7)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = model.init_params(net, arch, seed=8)
    assert any(not np.array_equal(a[k], c[k]) for k in a if a[k].size > 1)


def test_archs_capacity_matched():
    """Paper §3.1: 'similar structural complexity'. Enforce within 40%."""
    for net in model.NETS:
        counts = [model.param_count(model.init_params(net, a)) for a in model.ARCHS]
        assert max(counts) / min(counts) < 1.4, counts


@pytest.mark.parametrize("net,arch", ALL)
def test_train_step_descends(net, arch):
    params = model.init_params(net, arch)
    m, v, s = model.init_opt_state(params)
    x, y = _batch(net, 64)
    first = None
    for _ in range(25):
        params, m, v, s, loss, mae = model.train_step(params, m, v, s, x, y, arch)
        if first is None:
            first = float(loss)
    assert float(loss) < 0.5 * first, (first, float(loss))
    assert float(s) == 25.0
    assert float(mae) >= 0.0


@pytest.mark.parametrize("net,arch", ALL)
def test_flat_state_roundtrip(net, arch):
    """pack_state/unpack_state must be exact inverses in the declared order."""
    params = model.init_params(net, arch)
    m, v, s = model.init_opt_state(params)
    flat = model.pack_state(params, m, v, s)
    entries = model.state_entries(net, arch)
    assert len(flat) == len(entries)
    for t, (name, shape) in zip(flat, entries):
        assert tuple(t.shape) == shape, name
    p2, m2, v2, s2 = model.unpack_state(flat, net, arch)
    for k in params:
        np.testing.assert_array_equal(params[k], p2[k])
    np.testing.assert_array_equal(s, s2)


@pytest.mark.parametrize("net,arch", ALL)
def test_aot_entry_points_consistent(net, arch):
    """init→fwd through the AOT wrappers == direct apply().

    fwd consumes only the parameter tensors (state[:n_params]) — the
    contract the rust runtime relies on (see make_fwd_fn).
    """
    init_fn = model.make_init_fn(net, arch)
    fwd_fn = model.make_fwd_fn(net, arch)
    flat = init_fn()
    k = model.n_params(net, arch)
    x, _ = _batch(net, 16)
    (yhat,) = fwd_fn(*flat[:k], x)
    params = model.init_params(net, arch)
    np.testing.assert_allclose(yhat, model.apply(params, x, arch), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("net,arch", ALL)
def test_aot_train_fn_matches_train_step(net, arch):
    train_fn = model.make_train_fn(net, arch)
    flat = model.make_init_fn(net, arch)()
    x, y = _batch(net, model.OUT_DIM and 16)
    out = train_fn(*flat, x, y)
    assert len(out) == len(flat) + 2
    params, m, v, s = model.unpack_state(flat, net, arch)
    p2, m2, v2, s2, loss, mae = model.train_step(params, m, v, s, x, y, arch)
    ref_flat = model.pack_state(p2, m2, v2, s2)
    for a, b in zip(out[: len(flat)], ref_flat):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out[-2], loss, rtol=1e-5)
    np.testing.assert_allclose(out[-1], mae, rtol=1e-5)


def test_batch_size_invariance():
    """Per-example predictions must not depend on batch composition."""
    net, arch = "p1", "transformer"
    params = model.init_params(net, arch)
    x, _ = _batch(net, 48)
    full = model.apply(params, x, arch)
    half = model.apply(params, x[:24], arch)
    np.testing.assert_allclose(full[:24], half, rtol=1e-5, atol=1e-6)
