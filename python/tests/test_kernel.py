"""pytest: Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

hypothesis sweeps shapes/dtypes/activations; every kernel is checked for
forward agreement AND custom-VJP agreement against ``jax.grad`` of the
oracle. These properties are what make the AOT-compiled HLO trustworthy:
the L2 models call the kernels, never the refs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from compile.kernels import attention, fused_linear, gru_cell, layernorm, ref

jax.config.update("jax_enable_x64", False)

SET = settings(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def _keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5)}


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------


@SET
@given(
    m=st.integers(1, 130),
    k=st.integers(1, 96),
    n=st.integers(1, 130),
    act=st.sampled_from(["none", "relu", "tanh", "gelu"]),
    seed=st.integers(0, 2**16),
)
def test_fused_linear_matches_ref(m, k, n, act, seed):
    kx, kw, kb = _keys(seed, 3)
    x = _rand(kx, (m, k), jnp.float32)
    w = _rand(kw, (k, n), jnp.float32, 0.3)
    b = _rand(kb, (n,), jnp.float32)
    np.testing.assert_allclose(
        fused_linear(x, w, b, act), ref.linear_ref(x, w, b, act), **TOL[jnp.float32]
    )


@SET
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 48),
    n=st.integers(1, 70),
    act=st.sampled_from(["none", "relu", "tanh", "gelu"]),
    seed=st.integers(0, 2**16),
)
def test_fused_linear_grads_match_ref(m, k, n, act, seed):
    kx, kw, kb, kc = _keys(seed, 4)
    x = _rand(kx, (m, k), jnp.float32)
    w = _rand(kw, (k, n), jnp.float32, 0.3)
    b = _rand(kb, (n,), jnp.float32)
    # random cotangent-weighted scalar so every output element matters
    c = _rand(kc, (m, n), jnp.float32)

    def f_kernel(x, w, b):
        return jnp.sum(fused_linear(x, w, b, act) * c)

    def f_ref(x, w, b):
        return jnp.sum(ref.linear_ref(x, w, b, act) * c)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, bgrad in zip(gk, gr):
        np.testing.assert_allclose(a, bgrad, rtol=1e-4, atol=1e-4)


def test_fused_linear_rejects_unknown_activation():
    x = jnp.zeros((2, 3))
    with pytest.raises(ValueError):
        fused_linear(x, jnp.zeros((3, 4)), jnp.zeros((4,)), "swish")


@pytest.mark.parametrize("bm,bn", [(8, 8), (16, 128), (128, 16), (128, 128)])
def test_fused_linear_block_shape_invariance(bm, bn):
    """Tiling must never change the numbers — pure schedule choice."""
    kx, kw, kb = _keys(7, 3)
    x = _rand(kx, (57, 33), jnp.float32)
    w = _rand(kw, (33, 41), jnp.float32, 0.3)
    b = _rand(kb, (41,), jnp.float32)
    base = ref.linear_ref(x, w, b, "relu")
    np.testing.assert_allclose(
        fused_linear(x, w, b, "relu", block_m=bm, block_n=bn), base, **TOL[jnp.float32]
    )


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


@SET
@given(m=st.integers(1, 130), d=st.integers(2, 96), seed=st.integers(0, 2**16))
def test_layernorm_matches_ref(m, d, seed):
    kx, kg, kb = _keys(seed, 3)
    x = _rand(kx, (m, d), jnp.float32, 2.0)
    g = _rand(kg, (d,), jnp.float32)
    b = _rand(kb, (d,), jnp.float32)
    np.testing.assert_allclose(
        layernorm(x, g, b), ref.layernorm_ref(x, g, b), rtol=5e-5, atol=5e-5
    )


@SET
@given(m=st.integers(1, 40), d=st.integers(2, 48), seed=st.integers(0, 2**16))
def test_layernorm_grads_match_ref(m, d, seed):
    kx, kg, kb, kc = _keys(seed, 4)
    x = _rand(kx, (m, d), jnp.float32, 2.0)
    g = _rand(kg, (d,), jnp.float32)
    b = _rand(kb, (d,), jnp.float32)
    c = _rand(kc, (m, d), jnp.float32)
    gk = jax.grad(lambda *a: jnp.sum(layernorm(*a) * c), argnums=(0, 1, 2))(x, g, b)
    gr = jax.grad(lambda *a: jnp.sum(ref.layernorm_ref(*a) * c), argnums=(0, 1, 2))(x, g, b)
    for a, bgrad in zip(gk, gr):
        np.testing.assert_allclose(a, bgrad, rtol=2e-4, atol=2e-4)


def test_layernorm_normalizes():
    """With unit gain / zero shift the output rows are ~standardized."""
    x = _rand(jax.random.PRNGKey(3), (16, 64), jnp.float32, 5.0)
    y = layernorm(x, jnp.ones(64), jnp.zeros(64))
    np.testing.assert_allclose(jnp.mean(y, axis=-1), np.zeros(16), atol=1e-5)
    np.testing.assert_allclose(jnp.std(y, axis=-1), np.ones(16), atol=1e-2)


# ---------------------------------------------------------------------------
# gru_cell
# ---------------------------------------------------------------------------


@SET
@given(
    bsz=st.integers(1, 130),
    d=st.integers(1, 32),
    h=st.integers(1, 48),
    seed=st.integers(0, 2**16),
)
def test_gru_cell_matches_ref(bsz, d, h, seed):
    kx, kh, kw, ku, kb = _keys(seed, 5)
    x = _rand(kx, (bsz, d), jnp.float32)
    hs = _rand(kh, (bsz, h), jnp.float32)
    w = _rand(kw, (d, 3 * h), jnp.float32, 0.3)
    u = _rand(ku, (h, 3 * h), jnp.float32, 0.3)
    b = _rand(kb, (3 * h,), jnp.float32, 0.1)
    np.testing.assert_allclose(
        gru_cell(x, hs, w, u, b), ref.gru_cell_ref(x, hs, w, u, b), rtol=3e-5, atol=3e-5
    )


@SET
@given(bsz=st.integers(1, 33), d=st.integers(1, 16), h=st.integers(1, 24), seed=st.integers(0, 2**16))
def test_gru_cell_grads_match_ref(bsz, d, h, seed):
    kx, kh, kw, ku, kb, kc = _keys(seed, 6)
    x = _rand(kx, (bsz, d), jnp.float32)
    hs = _rand(kh, (bsz, h), jnp.float32)
    w = _rand(kw, (d, 3 * h), jnp.float32, 0.3)
    u = _rand(ku, (h, 3 * h), jnp.float32, 0.3)
    b = _rand(kb, (3 * h,), jnp.float32, 0.1)
    c = _rand(kc, (bsz, h), jnp.float32)
    gk = jax.grad(lambda *a: jnp.sum(gru_cell(*a) * c), argnums=tuple(range(5)))(x, hs, w, u, b)
    gr = jax.grad(lambda *a: jnp.sum(ref.gru_cell_ref(*a) * c), argnums=tuple(range(5)))(
        x, hs, w, u, b
    )
    for a, bgrad in zip(gk, gr):
        np.testing.assert_allclose(a, bgrad, rtol=2e-4, atol=2e-4)


def test_gru_cell_fixed_point_of_zero_update():
    """If the update gate saturates to 0 (huge negative z-bias), h' == h."""
    bsz, d, h = 4, 8, 8
    kx, kh = _keys(11, 2)
    x = _rand(kx, (bsz, d), jnp.float32)
    hs = _rand(kh, (bsz, h), jnp.float32)
    w = jnp.zeros((d, 3 * h))
    u = jnp.zeros((h, 3 * h))
    b = jnp.zeros((3 * h,)).at[h : 2 * h].set(-30.0)  # z ≈ 0
    np.testing.assert_allclose(gru_cell(x, hs, w, u, b), hs, atol=1e-5)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@SET
@given(
    bsz=st.integers(1, 40),
    heads=st.integers(1, 4),
    s=st.integers(1, 8),
    dh=st.integers(1, 24),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(bsz, heads, s, dh, seed):
    kq, kk, kv = _keys(seed, 3)
    q = _rand(kq, (bsz, heads, s, dh), jnp.float32)
    k = _rand(kk, (bsz, heads, s, dh), jnp.float32)
    v = _rand(kv, (bsz, heads, s, dh), jnp.float32)
    np.testing.assert_allclose(
        attention(q, k, v), ref.attention_ref(q, k, v), rtol=3e-5, atol=3e-5
    )


@SET
@given(
    bsz=st.integers(1, 12),
    heads=st.integers(1, 3),
    s=st.integers(1, 6),
    dh=st.integers(1, 12),
    seed=st.integers(0, 2**16),
)
def test_attention_grads_match_ref(bsz, heads, s, dh, seed):
    kq, kk, kv, kc = _keys(seed, 4)
    q = _rand(kq, (bsz, heads, s, dh), jnp.float32)
    k = _rand(kk, (bsz, heads, s, dh), jnp.float32)
    v = _rand(kv, (bsz, heads, s, dh), jnp.float32)
    c = _rand(kc, (bsz, heads, s, dh), jnp.float32)
    gk = jax.grad(lambda *a: jnp.sum(attention(*a) * c), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: jnp.sum(ref.attention_ref(*a) * c), argnums=(0, 1, 2))(q, k, v)
    for a, bgrad in zip(gk, gr):
        np.testing.assert_allclose(a, bgrad, rtol=2e-4, atol=2e-4)


def test_attention_uniform_when_scores_equal():
    """Identical keys ⇒ uniform probabilities ⇒ output = mean of values."""
    bsz, heads, s, dh = 2, 2, 5, 8
    q = _rand(jax.random.PRNGKey(0), (bsz, heads, s, dh), jnp.float32)
    k = jnp.ones((bsz, heads, s, dh))
    v = _rand(jax.random.PRNGKey(1), (bsz, heads, s, dh), jnp.float32)
    expect = jnp.broadcast_to(jnp.mean(v, axis=2, keepdims=True), v.shape)
    np.testing.assert_allclose(attention(q, k, v), expect, rtol=1e-5, atol=1e-5)


def test_attention_softmax_stability_large_scores():
    """Max-subtraction keeps huge logits finite."""
    q = jnp.full((1, 1, 4, 8), 100.0)
    k = jnp.full((1, 1, 4, 8), 100.0)
    v = _rand(jax.random.PRNGKey(2), (1, 1, 4, 8), jnp.float32)
    out = attention(q, k, v)
    assert bool(jnp.all(jnp.isfinite(out)))
