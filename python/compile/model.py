"""L2 — the GOGH estimator networks (P1 and P2) in JAX.

The paper (§3.1) evaluates three capacity-matched architectures for both
the initial-estimation network P1 (Eq. 1) and the refinement network P2
(Eq. 3): Feedforward (FF), Recurrent (RNN — a GRU here), and Transformer.
This module defines parameter init, forward pass, MSE/MAE loss, and a
full Adam training step for every (net × arch) pair. All dense algebra
goes through the L1 Pallas kernels (:mod:`compile.kernels`) so that the
AOT-lowered HLO contains the kernels' tiled schedules.

I/O contract (shared with the rust runtime via ``artifacts/manifest.json``):

* P1 input  (B, 32): ``Ψ_j2(8) ‖ Ψ_j3(8) ‖ a(6) ‖ T_{a,j2} ‖ T_{a,j3} ‖
  Ψ_j1(8)`` → output (B, 2) = ``(T̃_{a,j1}, T̃_{a,j3})``.
* P2 input  (B, 40; 34 used, zero-padded): ``Ψ_j1(8) ‖ Ψ_j2(8) ‖ a1(6) ‖
  a2(6) ‖ T̃_{a1,j1} ‖ T̃_{a1,j2} ‖ T_{a1,j1} ‖ T_{a1,j2} ‖ T̃_{a2,j1} ‖
  T̃_{a2,j2} ‖ 0⁶`` → output (B, 2) = ``(T̃ⁱ_{a2,j1}, T̃ⁱ_{a2,j2})``.

The RNN and Transformer variants view the input as ``T`` tokens of
``TOKEN_DIM = 8`` features (4 tokens for P1, 5 for P2) — the field groups
of the paper's tuples; FF flattens. Throughputs are pre-normalized to
``[0, 1]`` by the rust side (global scale in the manifest).

Everything here is build-time only: ``aot.py`` lowers ``init`` / ``fwd``
/ ``train_step`` once to HLO text and the rust runtime drives training
and inference through PJRT.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels import attention, fused_linear, gru_cell, layernorm

# ---------------------------------------------------------------------------
# Dimensions
# ---------------------------------------------------------------------------

TOKEN_DIM = 8
OUT_DIM = 2

#: net name -> (raw input dim, padded input dim, token count)
NETS: Dict[str, Tuple[int, int, int]] = {
    "p1": (32, 32, 4),
    "p2": (34, 40, 5),
}

ARCHS = ("ff", "rnn", "transformer")

# Capacity-matched sizes (≈20k params each; paper §3.1 requires
# "comparable numbers of layers, hidden units, and training configs").
FF_HIDDEN = (96, 96, 48)
RNN_EMBED = 48
RNN_HIDDEN = 64
TF_DMODEL = 48
TF_HEADS = 4
TF_MLP = 128

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
DEFAULT_LR = 1e-3

Params = Dict[str, jax.Array]


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


# ---------------------------------------------------------------------------
# Architectures
# ---------------------------------------------------------------------------


def init_ff(key: jax.Array, in_dim: int) -> Params:
    dims = (in_dim, *FF_HIDDEN, OUT_DIM)
    params: Params = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k = jax.random.split(key)
        params[f"w{i}"] = _glorot(k, (a, b))
        params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
    return params


def apply_ff(params: Params, x: jax.Array) -> jax.Array:
    n_layers = len(FF_HIDDEN) + 1
    h = x
    for i in range(n_layers):
        act = "relu" if i < n_layers - 1 else "none"
        h = fused_linear(h, params[f"w{i}"], params[f"b{i}"], act)
    return h


def init_rnn(key: jax.Array, in_dim: int) -> Params:
    del in_dim  # consumes tokens, not the flat vector
    ke, kw, ku, kh = jax.random.split(key, 4)
    return {
        "embed_w": _glorot(ke, (TOKEN_DIM, RNN_EMBED)),
        "embed_b": jnp.zeros((RNN_EMBED,), jnp.float32),
        "gru_w": _glorot(kw, (RNN_EMBED, 3 * RNN_HIDDEN)),
        "gru_u": _glorot(ku, (RNN_HIDDEN, 3 * RNN_HIDDEN)),
        "gru_b": jnp.zeros((3 * RNN_HIDDEN,), jnp.float32),
        "head_w": _glorot(kh, (RNN_HIDDEN, OUT_DIM)),
        "head_b": jnp.zeros((OUT_DIM,), jnp.float32),
    }


def apply_rnn(params: Params, x: jax.Array) -> jax.Array:
    bsz, in_dim = x.shape
    t = in_dim // TOKEN_DIM
    tokens = x.reshape(bsz, t, TOKEN_DIM)
    # shared token embedding through the fused kernel
    emb = fused_linear(
        tokens.reshape(bsz * t, TOKEN_DIM), params["embed_w"], params["embed_b"], "tanh"
    )
    emb = emb.reshape(bsz, t, RNN_EMBED)

    def step(h, xt):
        hn = gru_cell(xt, h, params["gru_w"], params["gru_u"], params["gru_b"])
        return hn, None

    h0 = jnp.zeros((bsz, RNN_HIDDEN), jnp.float32)
    hT, _ = jax.lax.scan(step, h0, jnp.transpose(emb, (1, 0, 2)))
    return fused_linear(hT, params["head_w"], params["head_b"], "none")


def init_transformer(key: jax.Array, in_dim: int) -> Params:
    t = in_dim // TOKEN_DIM
    keys = jax.random.split(key, 8)
    d = TF_DMODEL
    return {
        "embed_w": _glorot(keys[0], (TOKEN_DIM, d)),
        "embed_b": jnp.zeros((d,), jnp.float32),
        "pos": jax.random.normal(keys[1], (t, d), jnp.float32) * 0.02,
        "wqkv": _glorot(keys[2], (d, 3 * d)),
        "bqkv": jnp.zeros((3 * d,), jnp.float32),
        "wo": _glorot(keys[3], (d, d)),
        "bo": jnp.zeros((d,), jnp.float32),
        "ln1_g": jnp.ones((d,), jnp.float32),
        "ln1_b": jnp.zeros((d,), jnp.float32),
        "mlp_w1": _glorot(keys[4], (d, TF_MLP)),
        "mlp_b1": jnp.zeros((TF_MLP,), jnp.float32),
        "mlp_w2": _glorot(keys[5], (TF_MLP, d)),
        "mlp_b2": jnp.zeros((d,), jnp.float32),
        "ln2_g": jnp.ones((d,), jnp.float32),
        "ln2_b": jnp.zeros((d,), jnp.float32),
        "lnf_g": jnp.ones((d,), jnp.float32),
        "lnf_b": jnp.zeros((d,), jnp.float32),
        "head_w": _glorot(keys[6], (d, OUT_DIM)),
        "head_b": jnp.zeros((OUT_DIM,), jnp.float32),
    }


def apply_transformer(params: Params, x: jax.Array) -> jax.Array:
    bsz, in_dim = x.shape
    t = in_dim // TOKEN_DIM
    d, nh = TF_DMODEL, TF_HEADS
    dh = d // nh

    tokens = x.reshape(bsz * t, TOKEN_DIM)
    h = fused_linear(tokens, params["embed_w"], params["embed_b"], "none").reshape(bsz, t, d)
    h = h + params["pos"][None, :, :]

    # --- pre-LN multi-head self-attention block
    hn = layernorm(h.reshape(bsz * t, d), params["ln1_g"], params["ln1_b"]).reshape(bsz, t, d)
    qkv = fused_linear(hn.reshape(bsz * t, d), params["wqkv"], params["bqkv"], "none")
    qkv = qkv.reshape(bsz, t, 3, nh, dh).transpose(2, 0, 3, 1, 4)  # (3, B, H, T, Dh)
    att = attention(qkv[0], qkv[1], qkv[2])  # (B, H, T, Dh)
    att = att.transpose(0, 2, 1, 3).reshape(bsz * t, d)
    h = h + fused_linear(att, params["wo"], params["bo"], "none").reshape(bsz, t, d)

    # --- pre-LN MLP block
    hn = layernorm(h.reshape(bsz * t, d), params["ln2_g"], params["ln2_b"])
    m = fused_linear(hn, params["mlp_w1"], params["mlp_b1"], "gelu")
    m = fused_linear(m, params["mlp_w2"], params["mlp_b2"], "none")
    h = h + m.reshape(bsz, t, d)

    # --- final LN, mean pool, head
    hf = layernorm(h.reshape(bsz * t, d), params["lnf_g"], params["lnf_b"]).reshape(bsz, t, d)
    pooled = jnp.mean(hf, axis=1)
    return fused_linear(pooled, params["head_w"], params["head_b"], "none")


_INIT = {"ff": init_ff, "rnn": init_rnn, "transformer": init_transformer}
_APPLY = {"ff": apply_ff, "rnn": apply_rnn, "transformer": apply_transformer}


def init_params(net: str, arch: str, seed: int = 0) -> Params:
    """Seeded parameter init for network ``net`` in architecture ``arch``."""
    _, padded, _ = NETS[net]
    # stable across processes (no PYTHONHASHSEED dependence)
    tag = sum(ord(c) * 31**i for i, c in enumerate(f"{net}/{arch}")) % (2**31)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), tag)
    return _INIT[arch](key, padded)


def apply(params: Params, x: jax.Array, arch: str) -> jax.Array:
    """Forward pass: ``(B, padded_in) -> (B, 2)`` throughput estimates."""
    return _APPLY[arch](params, x)


def param_count(params: Params) -> int:
    return sum(int(p.size) for p in params.values())


# ---------------------------------------------------------------------------
# Loss + Adam train step
# ---------------------------------------------------------------------------


def loss_fn(params: Params, x: jax.Array, y: jax.Array, arch: str):
    """MSE loss (paper's training loss) + MAE (paper's reported metric)."""
    pred = apply(params, x, arch)
    err = pred - y
    return jnp.mean(jnp.square(err)), jnp.mean(jnp.abs(err))


def init_opt_state(params: Params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    m = {f"m_{k}": z for k, z in zeros.items()}
    v = {f"v_{k}": z for k, z in zeros.items()}
    return m, v, jnp.zeros((), jnp.float32)


@functools.partial(jax.jit, static_argnames=("arch", "lr"))
def train_step(
    params: Params,
    m: Params,
    v: Params,
    step: jax.Array,
    x: jax.Array,
    y: jax.Array,
    arch: str,
    lr: float = DEFAULT_LR,
):
    """One Adam step; returns updated (params, m, v, step, loss, mae)."""
    (loss, mae), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, x, y, arch)
    t = step + 1.0
    bc1 = 1.0 - ADAM_B1**t
    bc2 = 1.0 - ADAM_B2**t
    new_params, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        mk = ADAM_B1 * m[f"m_{k}"] + (1.0 - ADAM_B1) * g
        vk = ADAM_B2 * v[f"v_{k}"] + (1.0 - ADAM_B2) * jnp.square(g)
        new_m[f"m_{k}"] = mk
        new_v[f"v_{k}"] = vk
        new_params[k] = params[k] - lr * (mk / bc1) / (jnp.sqrt(vk / bc2) + ADAM_EPS)
    return new_params, new_m, new_v, t, loss, mae


# ---------------------------------------------------------------------------
# Flat-state view (the rust runtime's contract)
# ---------------------------------------------------------------------------


def state_entries(net: str, arch: str):
    """Deterministic (name, shape) list for the flattened runtime state.

    Order: params (sorted by name), then m_*, then v_*, then the scalar
    Adam step counter. The rust runtime treats this as an opaque buffer
    list; the manifest records names/shapes for debugging and checks.
    """
    params = init_params(net, arch)
    names = sorted(params)
    entries = [(n, tuple(params[n].shape)) for n in names]
    entries += [(f"m_{n}", tuple(params[n].shape)) for n in names]
    entries += [(f"v_{n}", tuple(params[n].shape)) for n in names]
    entries.append(("adam_step", ()))
    return entries


def pack_state(params: Params, m: Params, v: Params, step: jax.Array):
    names = sorted(params)
    flat = [params[n] for n in names]
    flat += [m[f"m_{n}"] for n in names]
    flat += [v[f"v_{n}"] for n in names]
    flat.append(step)
    return tuple(flat)


def unpack_state(flat, net: str, arch: str):
    names = sorted(init_params(net, arch))
    k = len(names)
    params = dict(zip(names, flat[:k]))
    m = {f"m_{n}": t for n, t in zip(names, flat[k : 2 * k])}
    v = {f"v_{n}": t for n, t in zip(names, flat[2 * k : 3 * k])}
    step = flat[3 * k]
    return params, m, v, step


# The three AOT entry points, defined over flat state ----------------------


def make_init_fn(net: str, arch: str, seed: int = 0):
    def init_fn():
        params = init_params(net, arch, seed)
        m, v, step = init_opt_state(params)
        return pack_state(params, m, v, step)

    return init_fn


def n_params(net: str, arch: str) -> int:
    """Number of parameter tensors (first entries of the flat state)."""
    return len(init_params(net, arch))


def make_fwd_fn(net: str, arch: str):
    """fwd takes ONLY the parameter tensors (not Adam state): the m/v/step
    tensors are unused in inference and StableHLO→HLO conversion prunes
    unused entry parameters, which would break the runtime's input arity.
    """

    def fwd_fn(*args):
        *params_flat, x = args
        names = sorted(init_params(net, arch))
        params = dict(zip(names, params_flat))
        return (apply(params, x, arch),)

    return fwd_fn


def make_train_fn(net: str, arch: str, lr: float = DEFAULT_LR):
    def train_fn(*args):
        *flat, x, y = args
        params, m, v, step = unpack_state(flat, net, arch)
        new_params, new_m, new_v, new_step, loss, mae = train_step(
            params, m, v, step, x, y, arch, lr
        )
        return (*pack_state(new_params, new_m, new_v, new_step), loss, mae)

    return train_fn
