"""Pure-jnp oracles for the L1 Pallas kernels.

Every kernel in this package has a reference implementation here written
with plain ``jax.numpy`` ops only. The pytest suite asserts
``assert_allclose(kernel(...), ref(...))`` over hypothesis-generated
shape/dtype sweeps — this file is the correctness ground truth for the
whole compiled stack (the L2 models call the kernels, never the refs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_ref(x: jax.Array, w: jax.Array, b: jax.Array, activation: str = "none") -> jax.Array:
    """``act(x @ w + b)`` — oracle for :func:`fused_linear.fused_linear`.

    Args:
      x: ``(M, K)`` input.
      w: ``(K, N)`` weight.
      b: ``(N,)`` bias.
      activation: one of ``"none" | "relu" | "tanh" | "gelu"``.
    """
    y = jnp.dot(x, w) + b[None, :]
    if activation == "relu":
        y = jax.nn.relu(y)
    elif activation == "tanh":
        y = jnp.tanh(y)
    elif activation == "gelu":
        y = jax.nn.gelu(y)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


def gru_cell_ref(
    x: jax.Array, h: jax.Array, w: jax.Array, u: jax.Array, b: jax.Array
) -> jax.Array:
    """One GRU step — oracle for :func:`gru_cell.gru_cell`.

    Gate layout along the last axis of ``w``/``u``/``b`` is ``[r, z, n]``
    (reset, update, candidate), matching the fused kernel.

    Args:
      x: ``(B, D)`` input at this step.
      h: ``(B, H)`` previous hidden state.
      w: ``(D, 3H)`` input projection.
      u: ``(H, 3H)`` recurrent projection.
      b: ``(3H,)`` bias.
    Returns:
      ``(B, H)`` next hidden state.
    """
    gx = jnp.dot(x, w) + b[None, :]
    gh = jnp.dot(h, u)
    rx, zx, nx = jnp.split(gx, 3, axis=-1)
    rh, zh, nh = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    return (1.0 - z) * h + z * n


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Scaled-dot-product attention — oracle for :func:`attention.attention`.

    Args:
      q, k, v: ``(B, H, S, Dh)`` per-head tensors.
    Returns:
      ``(B, H, S, Dh)``.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs, v)


def layernorm_ref(x: jax.Array, g: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    """LayerNorm over the last axis — oracle for :func:`fused_linear.layernorm`."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b
