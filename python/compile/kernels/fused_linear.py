"""Fused linear layer as a Pallas kernel: ``act(x @ w + b)``.

This is the dominant compute of every estimator variant (FF layers, the
GRU head, Transformer MLP/projections), so it is the L1 hot-spot. The
kernel tiles ``(M, K) x (K, N)`` over a ``(M/bm, N/bn)`` grid with the
full ``K`` reduction resident per program instance, fusing the bias add
and activation into the same VMEM residency — the TPU analogue of a CUDA
shared-memory tile kernel with a fused epilogue (DESIGN.md
§Hardware-Adaptation).

Autodiff: interpret-mode ``pallas_call`` has no built-in VJP, so the
public entry points carry ``jax.custom_vjp`` rules (the FlashAttention
pattern). The forward kernel additionally emits the pre-activation so the
backward pass never re-runs the matmul; the three backward matmuls
(``dz @ wᵀ``, ``xᵀ @ dz`` and the LayerNorm reductions) reuse the same
tiled kernel with ``activation="none"``.

``interpret=True`` everywhere: CPU PJRT cannot run Mosaic custom-calls;
the interpret lowering emits plain HLO that the rust runtime executes.

TPU sizing notes (for §Perf estimates, not enforced on CPU):
  * default tiles bm=128, bn=128 match the MXU systolic array;
  * VMEM per instance = bm*K + K*bn + 2*bm*bn + bn floats; at the largest
    model shape here (K=192) ≈ 82k f32 ≈ 328 KiB — far under the
    ~16 MiB/core VMEM budget, so the schedule is single-pass with no
    K-splitting.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ACTIVATIONS = ("none", "relu", "tanh", "gelu")


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _apply_act(z: jax.Array, activation: str) -> jax.Array:
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "tanh":
        return jnp.tanh(z)
    if activation == "gelu":
        return jax.nn.gelu(z)
    return z


def _act_grad(z: jax.Array, y: jax.Array, activation: str) -> jax.Array:
    """d act(z) / dz, using the saved pre-activation ``z`` (and ``y=act(z)``)."""
    if activation == "relu":
        return jnp.where(z > 0.0, 1.0, 0.0)
    if activation == "tanh":
        return 1.0 - jnp.square(y)
    if activation == "gelu":
        # d/dz [z * Φ(z)] with the tanh approximation jax.nn.gelu uses.
        c = jnp.sqrt(2.0 / jnp.pi).astype(z.dtype)
        t = jnp.tanh(c * (z + 0.044715 * z**3))
        return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t**2) * c * (1.0 + 3 * 0.044715 * z**2)
    return jnp.ones_like(z)


def _linear_kernel(x_ref, w_ref, b_ref, y_ref, z_ref, *, activation: str):
    """One ``(bm, bn)`` output tile: full-K matmul + bias + activation.

    Emits both ``y = act(z)`` and the pre-activation ``z`` (backward reuse).
    """
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    z = acc + b_ref[...][None, :]
    z_ref[...] = z.astype(z_ref.dtype)
    y_ref[...] = _apply_act(z, activation).astype(y_ref.dtype)


def _linear_pallas(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str,
    block_m: int,
    block_n: int,
):
    """Raw tiled pallas call; returns ``(y, z)`` both ``(M, N)``."""
    m, k = x.shape
    _, n = w.shape
    # Shrink tiles to the problem, then pad the problem to the tiles so the
    # grid divides exactly. Padding contributes zeros to the reduction and
    # is sliced off the outputs.
    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    wp = jnp.pad(w, ((0, 0), (0, np_ - n))) if np_ != n else w
    bp = jnp.pad(b, (0, np_ - n)) if np_ != n else b

    y, z = pl.pallas_call(
        functools.partial(_linear_kernel, activation=activation),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), x.dtype),
            jax.ShapeDtypeStruct((mp, np_), x.dtype),
        ],
        interpret=True,
    )(xp, wp, bp)
    return y[:m, :n], z[:m, :n]


def _matmul(a: jax.Array, bmat: jax.Array) -> jax.Array:
    """Plain tiled matmul through the same pallas kernel (backward reuse)."""
    zero = jnp.zeros((bmat.shape[1],), a.dtype)
    y, _ = _linear_pallas(a, bmat, zero, "none", 128, 128)
    return y


@functools.lru_cache(maxsize=None)
def _make_linear(activation: str, block_m: int, block_n: int):
    @jax.custom_vjp
    def linear(x, w, b):
        y, _ = _linear_pallas(x, w, b, activation, block_m, block_n)
        return y

    def fwd(x, w, b):
        y, z = _linear_pallas(x, w, b, activation, block_m, block_n)
        return y, (x, w, z, y)

    def bwd(res, dy):
        x, w, z, y = res
        dz = dy * _act_grad(z, y, activation)
        dx = _matmul(dz, w.T)
        dw = _matmul(x.T, dz)
        db = jnp.sum(dz, axis=0)
        return dx, dw, db

    linear.defvjp(fwd, bwd)
    return linear


def fused_linear(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str = "none",
    block_m: int = 128,
    block_n: int = 128,
) -> jax.Array:
    """``act(x @ w + b)`` with Pallas tiling; matches :func:`ref.linear_ref`.

    Differentiable (custom VJP; backward matmuls reuse the tiled kernel).

    Args:
      x: ``(M, K)``.
      w: ``(K, N)``.
      b: ``(N,)``.
      activation: ``"none" | "relu" | "tanh" | "gelu"``.
      block_m / block_n: output tile shape (MXU-aligned by default).
    Returns:
      ``(M, N)`` in ``x.dtype``.
    """
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert b.shape == (n,), (b.shape, n)
    return _make_linear(activation, block_m, block_n)(x, w, b)


# ---------------------------------------------------------------------------
# LayerNorm
# ---------------------------------------------------------------------------


def _layernorm_kernel(x_ref, g_ref, b_ref, y_ref, xhat_ref, rstd_ref, *, eps: float):
    """Row-tile LayerNorm: mean/var/scale fused in one VMEM pass.

    Also emits the normalized input and reciprocal std for the backward.
    """
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = (x - mu) * rstd
    xhat_ref[...] = xhat.astype(xhat_ref.dtype)
    rstd_ref[...] = rstd[:, 0].astype(rstd_ref.dtype)
    y_ref[...] = (xhat * g_ref[...][None, :] + b_ref[...][None, :]).astype(y_ref.dtype)


def _layernorm_pallas(x, g, b, eps: float, block_m: int):
    m, d = x.shape
    bm = min(block_m, _ceil_to(m, 8))
    mp = _ceil_to(m, bm)
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    y, xhat, rstd = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, d), x.dtype),
            jax.ShapeDtypeStruct((mp, d), x.dtype),
            jax.ShapeDtypeStruct((mp,), x.dtype),
        ],
        interpret=True,
    )(xp, g, b)
    return y[:m], xhat[:m], rstd[:m]


@functools.lru_cache(maxsize=None)
def _make_layernorm(eps: float, block_m: int):
    @jax.custom_vjp
    def ln(x, g, b):
        y, _, _ = _layernorm_pallas(x, g, b, eps, block_m)
        return y

    def fwd(x, g, b):
        y, xhat, rstd = _layernorm_pallas(x, g, b, eps, block_m)
        return y, (xhat, rstd, g)

    def bwd(res, dy):
        xhat, rstd, g = res
        d = xhat.shape[-1]
        dg = jnp.sum(dy * xhat, axis=0)
        db = jnp.sum(dy, axis=0)
        dxhat = dy * g[None, :]
        # standard LN backward: dx = rstd * (dxhat - mean(dxhat) - xhat*mean(dxhat*xhat))
        dx = rstd[:, None] * (
            dxhat
            - jnp.mean(dxhat, axis=-1, keepdims=True)
            - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True)
        )
        del d
        return dx, dg, db

    ln.defvjp(fwd, bwd)
    return ln


def layernorm(
    x: jax.Array, g: jax.Array, b: jax.Array, eps: float = 1e-5, block_m: int = 128
) -> jax.Array:
    """LayerNorm over the last axis; matches :func:`ref.layernorm_ref`.

    Differentiable (custom VJP).

    Args:
      x: ``(M, D)``.
      g, b: ``(D,)`` scale and shift.
    """
    m, d = x.shape
    assert g.shape == (d,) and b.shape == (d,), (x.shape, g.shape, b.shape)
    return _make_layernorm(eps, block_m)(x, g, b)
