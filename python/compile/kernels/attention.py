"""Fused scaled-dot-product attention as a Pallas kernel.

The Transformer estimator variant attends over the 4–5 field-group tokens
of a P1/P2 input. Sequence lengths are tiny, so unlike FlashAttention
there is no need to stream K/V tiles: one program instance holds the
whole ``(S, S)`` score matrix for a batch×head tile in VMEM and fuses
scale → softmax → value-weighting in a single pass (the same "never
spill the scores" insight FlashAttention applies at large S with
streaming; see DESIGN.md §Hardware-Adaptation).

Autodiff: ``jax.custom_vjp`` with the softmax probabilities stashed by
the forward kernel — the standard SDPA backward, all-batched einsums over
tiny ``(S, S)`` tiles.

Grid: ``(B*H / block_bh,)`` over flattened batch×head rows.
``interpret=True`` as everywhere in this package.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_linear import _ceil_to


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, p_ref, *, scale: float):
    q = q_ref[...]  # (bh, S, Dh)
    k = k_ref[...]
    v = v_ref[...]
    scores = jnp.einsum("bsd,btd->bst", q, k, preferred_element_type=jnp.float32) * scale
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    p_ref[...] = probs.astype(p_ref.dtype)
    o_ref[...] = jnp.einsum("bst,btd->bsd", probs, v, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def _attn_pallas(qf, kf, vf, scale: float, block_bh: int):
    bh, s, dh = qf.shape
    bbh = min(block_bh, _ceil_to(bh, 8))
    bhp = _ceil_to(bh, bbh)
    if bhp != bh:
        pad = ((0, bhp - bh), (0, 0), (0, 0))
        qf, kf, vf = jnp.pad(qf, pad), jnp.pad(kf, pad), jnp.pad(vf, pad)
    spec = pl.BlockSpec((bbh, s, dh), lambda i: (i, 0, 0))
    pspec = pl.BlockSpec((bbh, s, s), lambda i: (i, 0, 0))
    out, probs = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=(bhp // bbh,),
        in_specs=[spec] * 3,
        out_specs=[spec, pspec],
        out_shape=[
            jax.ShapeDtypeStruct((bhp, s, dh), qf.dtype),
            jax.ShapeDtypeStruct((bhp, s, s), qf.dtype),
        ],
        interpret=True,
    )(qf, kf, vf)
    return out[:bh], probs[:bh]


@functools.lru_cache(maxsize=None)
def _make_attention(scale: float, block_bh: int):
    @jax.custom_vjp
    def attn(qf, kf, vf):
        return _attn_pallas(qf, kf, vf, scale, block_bh)[0]

    def fwd(qf, kf, vf):
        out, probs = _attn_pallas(qf, kf, vf, scale, block_bh)
        return out, (qf, kf, vf, probs)

    def bwd(res, do):
        qf, kf, vf, p = res
        dv = jnp.einsum("bst,bsd->btd", p, do)
        dp = jnp.einsum("bsd,btd->bst", do, vf)
        # softmax backward: ds = p * (dp - sum_t(dp * p))
        ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
        dq = jnp.einsum("bst,btd->bsd", ds, kf) * scale
        dk = jnp.einsum("bst,bsd->btd", ds, qf) * scale
        return dq, dk, dv

    attn.defvjp(fwd, bwd)
    return attn


def attention(q: jax.Array, k: jax.Array, v: jax.Array, block_bh: int = 64) -> jax.Array:
    """Fused SDPA; matches :func:`ref.attention_ref`. Differentiable.

    Args:
      q, k, v: ``(B, H, S, Dh)`` per-head tensors.
    Returns:
      ``(B, H, S, Dh)``.
    """
    bsz, heads, s, dh = q.shape
    assert k.shape == q.shape and v.shape == q.shape
    scale = 1.0 / float(dh) ** 0.5
    bh = bsz * heads
    out = _make_attention(scale, block_bh)(
        q.reshape(bh, s, dh), k.reshape(bh, s, dh), v.reshape(bh, s, dh)
    )
    return out.reshape(bsz, heads, s, dh)
