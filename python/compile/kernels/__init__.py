"""L1 — Pallas kernels for the GOGH estimator hot-spots.

Public surface:
  * :func:`fused_linear.fused_linear` — tiled ``act(x @ w + b)``.
  * :func:`fused_linear.layernorm` — fused row LayerNorm.
  * :func:`gru_cell.gru_cell` — fused GRU recurrence step.
  * :func:`attention.attention` — fused scaled-dot-product attention.
  * :mod:`ref` — pure-jnp oracles for all of the above.

All kernels lower with ``interpret=True`` so the emitted HLO runs on the
CPU PJRT client the rust runtime uses (see DESIGN.md).
"""

from .attention import attention
from .fused_linear import fused_linear, layernorm
from .gru_cell import gru_cell
from . import ref

__all__ = ["attention", "fused_linear", "layernorm", "gru_cell", "ref"]
