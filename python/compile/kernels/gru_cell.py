"""Fused GRU cell as a Pallas kernel.

The RNN estimator variant (paper §3.1) runs a GRU over the field-group
token sequence of the P1/P2 inputs. The per-step compute — two
``(B, ·) x (·, 3H)`` matmuls plus the gate nonlinearities — is fused into
a single kernel so the ``(B, 3H)`` gate tiles never leave VMEM between
the matmuls and the sigmoid/tanh epilogue. On real TPU this is one MXU
pass per projection with the elementwise gates on the VPU; here it runs
``interpret=True`` (see fused_linear.py).

Autodiff: ``jax.custom_vjp``. The forward kernel stashes the gate
activations ``(r, z, n, nh)`` so the backward pass is pure elementwise
algebra plus four matmuls, which reuse the tiled pallas matmul from
:mod:`fused_linear`.

Gate layout along the ``3H`` axis is ``[r, z, n]``, matching
:func:`ref.gru_cell_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_linear import _ceil_to, _matmul


def _gru_kernel(x_ref, h_ref, w_ref, u_ref, b_ref, o_ref, r_ref, z_ref, n_ref, nh_ref, *, hidden: int):
    x = x_ref[...]
    h = h_ref[...]
    gx = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32) + b_ref[...][None, :]
    gh = jnp.dot(h, u_ref[...], preferred_element_type=jnp.float32)
    rx, zx, nx = gx[:, :hidden], gx[:, hidden : 2 * hidden], gx[:, 2 * hidden :]
    rh, zh, nh = gh[:, :hidden], gh[:, hidden : 2 * hidden], gh[:, 2 * hidden :]
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    o_ref[...] = ((1.0 - z) * h + z * n).astype(o_ref.dtype)
    r_ref[...] = r.astype(r_ref.dtype)
    z_ref[...] = z.astype(z_ref.dtype)
    n_ref[...] = n.astype(n_ref.dtype)
    nh_ref[...] = nh.astype(nh_ref.dtype)


def _gru_pallas(x, h, w, u, b, block_b: int):
    bsz, d = x.shape
    hdim = h.shape[-1]
    bb = min(block_b, _ceil_to(bsz, 8))
    bp = _ceil_to(bsz, bb)
    xp = jnp.pad(x, ((0, bp - bsz), (0, 0))) if bp != bsz else x
    hp = jnp.pad(h, ((0, bp - bsz), (0, 0))) if bp != bsz else h

    spec_bh = pl.BlockSpec((bb, hdim), lambda i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(_gru_kernel, hidden=hdim),
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            spec_bh,
            pl.BlockSpec((d, 3 * hdim), lambda i: (0, 0)),
            pl.BlockSpec((hdim, 3 * hdim), lambda i: (0, 0)),
            pl.BlockSpec((3 * hdim,), lambda i: (0,)),
        ],
        out_specs=[spec_bh] * 5,
        out_shape=[jax.ShapeDtypeStruct((bp, hdim), x.dtype)] * 5,
        interpret=True,
    )(xp, hp, w, u, b)
    return tuple(o[:bsz] for o in outs)  # (h', r, z, n, nh)


@functools.lru_cache(maxsize=None)
def _make_gru(block_b: int):
    @jax.custom_vjp
    def cell(x, h, w, u, b):
        return _gru_pallas(x, h, w, u, b, block_b)[0]

    def fwd(x, h, w, u, b):
        hn, r, z, n, nh = _gru_pallas(x, h, w, u, b, block_b)
        return hn, (x, h, w, u, r, z, n, nh)

    def bwd(res, dhn):
        x, h, w, u, r, z, n, nh = res
        # h' = (1-z)*h + z*n,  n = tanh(nx + r*nh),  r/z = sigmoid(pre)
        dz = dhn * (n - h)
        dn = dhn * z
        dh = dhn * (1.0 - z)
        dn_pre = dn * (1.0 - jnp.square(n))
        dr = dn_pre * nh
        dnh = dn_pre * r
        dz_pre = dz * z * (1.0 - z)
        dr_pre = dr * r * (1.0 - r)
        dgx = jnp.concatenate([dr_pre, dz_pre, dn_pre], axis=-1)  # (B, 3H)
        dgh = jnp.concatenate([dr_pre, dz_pre, dnh], axis=-1)
        dx = _matmul(dgx, w.T)
        dw = _matmul(x.T, dgx)
        db = jnp.sum(dgx, axis=0)
        dh = dh + _matmul(dgh, u.T)
        du = _matmul(h.T, dgh)
        return dx, dh, dw, du, db

    cell.defvjp(fwd, bwd)
    return cell


def gru_cell(
    x: jax.Array,
    h: jax.Array,
    w: jax.Array,
    u: jax.Array,
    b: jax.Array,
    block_b: int = 128,
) -> jax.Array:
    """One fused GRU step; matches :func:`ref.gru_cell_ref`. Differentiable.

    Args:
      x: ``(B, D)`` step input.
      h: ``(B, H)`` previous hidden state.
      w: ``(D, 3H)`` input projection.
      u: ``(H, 3H)`` recurrent projection.
      b: ``(3H,)`` bias.
    Returns:
      ``(B, H)`` next hidden state.
    """
    bsz, d = x.shape
    hdim = h.shape[-1]
    assert h.shape == (bsz, hdim)
    assert w.shape == (d, 3 * hdim), (w.shape, d, hdim)
    assert u.shape == (hdim, 3 * hdim), (u.shape, hdim)
    assert b.shape == (3 * hdim,), (b.shape, hdim)
    return _make_gru(block_b)(x, h, w, u, b)
