"""AOT lowering: JAX → HLO **text** → ``artifacts/`` for the rust runtime.

Emits, for every (net × arch) pair of the GOGH estimators
(p1/p2 × ff/rnn/transformer):

  * ``{net}_{arch}_init.hlo.txt``  — ``() -> state`` seeded param+Adam init
  * ``{net}_{arch}_fwd.hlo.txt``   — ``(state…, x) -> (yhat,)``
  * ``{net}_{arch}_train.hlo.txt`` — ``(state…, x, y) -> (state…, loss, mae)``

plus ``manifest.json`` describing every artifact's I/O so the rust
runtime can drive them blindly.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True``
— the rust side unwraps the single tuple output.

Run via ``make artifacts`` (no-op when inputs are unchanged):
    cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Fixed AOT batch sizes (PJRT executables are shape-specialized; the rust
# side pads partial batches and slices results).
TRAIN_BATCH = 256
PRED_BATCH = 256

MANIFEST_VERSION = 2


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_model(net: str, arch: str, out_dir: pathlib.Path, lr: float) -> dict:
    """Lower init/fwd/train for one (net, arch); returns its manifest entry."""
    raw_in, padded_in, tokens = model.NETS[net]
    entries = model.state_entries(net, arch)
    state_specs = [_spec(s) for _, s in entries]
    n_params = model.n_params(net, arch)
    param_specs = state_specs[:n_params]
    x_train = _spec((TRAIN_BATCH, padded_in))
    y_train = _spec((TRAIN_BATCH, model.OUT_DIM))
    x_pred = _spec((PRED_BATCH, padded_in))

    key = f"{net}_{arch}"
    files = {}
    for kind, fn, args in (
        ("init", model.make_init_fn(net, arch), ()),
        # fwd consumes params only — unused Adam state would be pruned
        # from the HLO entry signature (see model.make_fwd_fn).
        ("fwd", model.make_fwd_fn(net, arch), (*param_specs, x_pred)),
        ("train", model.make_train_fn(net, arch, lr), (*state_specs, x_train, y_train)),
    ):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{key}_{kind}.hlo.txt"
        (out_dir / fname).write_text(text)
        files[kind] = fname
        print(f"  wrote {fname} ({len(text) / 1024:.0f} KiB)")

    return {
        "net": net,
        "arch": arch,
        "input_dim": raw_in,
        "padded_dim": padded_in,
        "tokens": tokens,
        "out_dim": model.OUT_DIM,
        "train_batch": TRAIN_BATCH,
        "pred_batch": PRED_BATCH,
        "lr": lr,
        "param_count": model.param_count(model.init_params(net, arch)),
        "n_params": n_params,
        "state": [{"name": n, "shape": list(s)} for n, s in entries],
        "files": files,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument("--lr", type=float, default=model.DEFAULT_LR, help="Adam learning rate baked into train steps")
    ap.add_argument("--only", default=None, help="comma-separated net_arch keys to lower (default: all)")
    # legacy single-file flag kept so `make` prerequisites stay simple
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out).parent if args.out else pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {
        "version": MANIFEST_VERSION,
        "token_dim": model.TOKEN_DIM,
        "models": {},
    }
    for net in model.NETS:
        for arch in model.ARCHS:
            key = f"{net}_{arch}"
            if only and key not in only:
                continue
            print(f"lowering {key} ...")
            manifest["models"][key] = lower_model(net, arch, out_dir, args.lr)

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'} ({len(manifest['models'])} models)")
    if only is None:
        # stamp file used by `make` to detect completion of a FULL build
        (out_dir / ".stamp").write_text("ok\n")


if __name__ == "__main__":
    main()
