#!/usr/bin/env python3
"""Bench regression gate: compare a fresh BENCH_<name>.json against the
committed baseline and fail when mean decision latency regresses more
than the tolerance.

Usage: bench_gate.py <measured.json> <baseline.json> [tolerance]

The tolerance is a fraction on top of the baseline (default 0.25, i.e.
fail above baseline * 1.25). Stdlib only — runs anywhere python3 does.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) < 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        measured = json.load(f)
    with open(sys.argv[2]) as f:
        baseline = json.load(f)
    tolerance = float(sys.argv[3]) if len(sys.argv) > 3 else 0.25

    if measured.get("bench") != baseline.get("bench"):
        print(
            f"bench mismatch: measured {measured.get('bench')!r} "
            f"vs baseline {baseline.get('bench')!r}"
        )
        return 2
    if measured.get("jobs") != baseline.get("jobs"):
        print(
            f"warning: trace sizes differ (measured {measured.get('jobs')} "
            f"vs baseline {baseline.get('jobs')}) — latency compare may be apples/oranges"
        )

    mean = float(measured["mean_decision_ms"])
    base = float(baseline["mean_decision_ms"])
    limit = base * (1.0 + tolerance)
    print(
        f"mean decision latency: measured {mean:.3f} ms, baseline {base:.3f} ms, "
        f"limit {limit:.3f} ms (+{tolerance:.0%})"
    )
    print(
        f"context: explored_nodes={measured.get('explored_nodes')}, "
        f"peak_rss_bytes={measured.get('peak_rss_bytes')}"
    )
    if mean > limit:
        print(f"FAIL: mean decision latency regressed >{tolerance:.0%} vs the committed baseline")
        return 1
    print("OK: within the regression budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
