#!/usr/bin/env python3
"""Bench regression gate: compare a fresh BENCH_<name>.json against the
committed baseline and fail on regressions beyond the tolerance.

Usage: bench_gate.py <measured.json> <baseline.json> [tolerance]

Gated fields:
  * mean_decision_ms  — required in both files; fail above
                        baseline * (1 + tolerance).
  * p99_decision_ms   — gated the same way when the baseline carries a
                        nonzero value (decision-tail regression).
  * explored_nodes    — gated the same way when the baseline carries a
                        nonzero value (solver-work regression).
  * peak_rss_bytes    — gated when both sides carry a nonzero value
                        (0 means "unknown platform", never "tiny").

The tolerance is a fraction on top of the baseline (default 0.25, i.e.
fail above baseline * 1.25). Stdlib only — runs anywhere python3 does.
Importable for tests: `gate(measured, baseline, tolerance)` returns the
exit code (0 ok, 1 regression, 2 malformed input).
"""

import json
import sys


def _check(name, measured, baseline, tolerance, required):
    """Gate one field. Returns 0 (ok/skipped), 1 (regression), 2 (malformed)."""
    if name not in measured:
        if required:
            print(f"malformed measurement: missing required field {name!r}")
            return 2
        # the measured record is always freshly emitted by HEAD: a gated
        # field vanishing from it while the baseline still carries one
        # means the gate just got silently disabled — fail loudly
        try:
            baseline_gates = float(baseline.get(name, 0.0)) > 0.0
        except (TypeError, ValueError):
            baseline_gates = False
        if baseline_gates:
            print(f"malformed measurement: gated field {name!r} vanished from the record")
            return 2
        print(f"{name}: absent from measurement and baseline — skipped")
        return 0
    if name not in baseline:
        if required:
            print(f"malformed baseline: missing required field {name!r}")
            return 2
        print(f"{name}: no baseline value — skipped")
        return 0
    try:
        meas = float(measured[name])
        base = float(baseline[name])
    except (TypeError, ValueError):
        print(f"malformed input: non-numeric {name!r}")
        return 2
    if base <= 0.0:
        if required:
            print(f"malformed baseline: non-positive {name!r} ({base}) disables the gate")
            return 2
        print(f"{name}: no usable baseline ({base}) — skipped")
        return 0
    if name == "peak_rss_bytes" and meas == 0.0:
        print(f"{name}: unmeasurable on this platform (measured 0) — skipped")
        return 0
    limit = base * (1.0 + tolerance)
    verdict = "FAIL" if meas > limit else "ok"
    print(f"{name}: measured {meas:.3f}, baseline {base:.3f}, limit {limit:.3f} -> {verdict}")
    return 1 if meas > limit else 0


def gate(measured, baseline, tolerance=0.25):
    """Gate a measured record dict against a baseline dict."""
    if measured.get("bench") != baseline.get("bench"):
        print(
            f"bench mismatch: measured {measured.get('bench')!r} "
            f"vs baseline {baseline.get('bench')!r}"
        )
        return 2
    if measured.get("jobs") != baseline.get("jobs"):
        print(
            f"warning: trace sizes differ (measured {measured.get('jobs')} "
            f"vs baseline {baseline.get('jobs')}) — compare may be apples/oranges"
        )
    worst = 0
    for name, required in [
        ("mean_decision_ms", True),
        ("p99_decision_ms", False),
        ("explored_nodes", False),
        ("peak_rss_bytes", False),
    ]:
        rc = _check(name, measured, baseline, tolerance, required)
        if rc == 2:
            return 2
        worst = max(worst, rc)
    if worst:
        print(f"FAIL: regression >{tolerance:.0%} vs the committed baseline")
    else:
        print("OK: within the regression budget")
    return worst


def main() -> int:
    if len(sys.argv) < 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        measured = json.load(f)
    with open(sys.argv[2]) as f:
        baseline = json.load(f)
    tolerance = float(sys.argv[3]) if len(sys.argv) > 3 else 0.25
    return gate(measured, baseline, tolerance)


if __name__ == "__main__":
    sys.exit(main())
