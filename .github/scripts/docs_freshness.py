#!/usr/bin/env python3
"""Fail CI if a CLI flag read by the binaries is missing from docs/CLI.md.

The binaries read flags exclusively through the `Args` accessors
(`get` / `get_parse` / `has`), so a regex over the two entry points is
a complete inventory. Every flag found there must appear in
docs/CLI.md spelled `--flag`, which keeps the CLI reference from
silently rotting as flags are added.

Usage: python3 .github/scripts/docs_freshness.py  (run from repo root)
"""

import re
import sys
from pathlib import Path

SOURCES = [
    Path("rust/src/main.rs"),
    Path("rust/src/bin/goghd.rs"),
]
DOC = Path("docs/CLI.md")

FLAG_RE = re.compile(r'args\.(?:get|get_parse|has)(?:::<[^>]+>)?\s*\(\s*"([a-z0-9-]+)"\s*\)')


def main() -> int:
    flags: dict[str, list[str]] = {}
    for src in SOURCES:
        for flag in FLAG_RE.findall(src.read_text()):
            flags.setdefault(flag, []).append(str(src))
    if not flags:
        print("docs_freshness: no flags found — the extraction regex is stale", file=sys.stderr)
        return 1

    doc = DOC.read_text()
    missing = sorted(f for f in flags if f"--{f}" not in doc)
    if missing:
        for f in missing:
            print(f"docs_freshness: --{f} (read by {', '.join(flags[f])}) "
                  f"is not documented in {DOC}", file=sys.stderr)
        return 1

    print(f"docs_freshness: all {len(flags)} flags documented in {DOC}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
