#!/usr/bin/env python3
"""Fail CI if the docs fall behind the code they describe.

Two checks, both pure-regex so they run without a toolchain:

1. CLI flags: the binaries read flags exclusively through the `Args`
   accessors (`get` / `get_parse` / `has`), so a regex over the two
   entry points is a complete inventory. Every flag found there must
   appear in docs/CLI.md spelled `--flag`.
2. Lint rules: every rule declared in the `RULES` table of
   rust/src/lint/rules.rs (`name: "<rule>"`) must be documented in
   docs/LINTS.md, which `cargo doc` includes at `gogh::lint`.

Usage: python3 .github/scripts/docs_freshness.py  (run from repo root)
"""

import re
import sys
from pathlib import Path

SOURCES = [
    Path("rust/src/main.rs"),
    Path("rust/src/bin/goghd.rs"),
]
DOC = Path("docs/CLI.md")

FLAG_RE = re.compile(r'args\.(?:get|get_parse|has)(?:::<[^>]+>)?\s*\(\s*"([a-z0-9-]+)"\s*\)')

LINT_SRC = Path("rust/src/lint/rules.rs")
LINT_DOC = Path("docs/LINTS.md")

RULE_RE = re.compile(r'name:\s*"([a-z0-9-]+)"')


def check_cli_flags() -> int:
    flags: dict[str, list[str]] = {}
    for src in SOURCES:
        for flag in FLAG_RE.findall(src.read_text()):
            flags.setdefault(flag, []).append(str(src))
    if not flags:
        print("docs_freshness: no flags found — the extraction regex is stale", file=sys.stderr)
        return 1

    doc = DOC.read_text()
    missing = sorted(f for f in flags if f"--{f}" not in doc)
    if missing:
        for f in missing:
            print(f"docs_freshness: --{f} (read by {', '.join(flags[f])}) "
                  f"is not documented in {DOC}", file=sys.stderr)
        return 1

    print(f"docs_freshness: all {len(flags)} flags documented in {DOC}")
    return 0


def check_lint_rules() -> int:
    rules = RULE_RE.findall(LINT_SRC.read_text())
    if not rules:
        print(f"docs_freshness: no rules found in {LINT_SRC} — "
              "the extraction regex is stale", file=sys.stderr)
        return 1

    doc = LINT_DOC.read_text()
    missing = sorted(r for r in set(rules) if f"`{r}`" not in doc)
    if missing:
        for r in missing:
            print(f"docs_freshness: lint rule {r} (declared in {LINT_SRC}) "
                  f"is not documented in {LINT_DOC}", file=sys.stderr)
        return 1

    print(f"docs_freshness: all {len(set(rules))} lint rules documented in {LINT_DOC}")
    return 0


def main() -> int:
    return check_cli_flags() | check_lint_rules()


if __name__ == "__main__":
    sys.exit(main())
