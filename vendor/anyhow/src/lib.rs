//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The GOGH workspace builds in environments with no crates.io access,
//! so this shim is vendored as a path dependency under the same crate
//! name. It covers exactly the surface the repo uses:
//!
//! * [`Error`] / [`Result`] — a String-backed error with a preserved
//!   `Display` chain,
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros,
//! * a blanket `From<E: std::error::Error>` so `?` converts std errors,
//! * [`Context`] for `.context(..)` / `.with_context(..)` on results
//!   and options.
//!
//! Like real `anyhow`, [`Error`] deliberately does **not** implement
//! `std::error::Error` (that would conflict with the blanket `From`).
//! Swapping back to the registry crate is a one-line change in the
//! workspace manifest.

use std::fmt;

/// A catch-all error: formatted message plus optional source chain text.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            msg: message.to_string(),
        }
    }

    /// Prepend context, keeping the original message in the chain.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(&format!(": {s}"));
            src = s.source();
        }
        Self { msg }
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

/// `.context(..)` / `.with_context(..)` on results and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad {} ({})", "thing", 7);
        assert_eq!(e.to_string(), "bad thing (7)");
        let r: Result<()> = (|| {
            ensure!(1 + 1 == 2, "math works");
            bail!("stop {}", "here");
        })();
        assert_eq!(r.unwrap_err().to_string(), "stop here");
    }

    #[test]
    fn ensure_without_message() {
        let r: Result<()> = (|| {
            ensure!(false);
            Ok(())
        })();
        assert!(r.unwrap_err().to_string().contains("condition failed"));
    }

    #[test]
    fn context_wraps() {
        let r: Result<u32> = None.context("missing value");
        assert_eq!(r.unwrap_err().to_string(), "missing value");
        let r: Result<()> = io_fail().context("loading config");
        assert!(r.unwrap_err().to_string().starts_with("loading config: "));
    }
}
