//! Build-only stub of the `xla-rs` PJRT bindings.
//!
//! The GOGH runtime layer (`gogh::runtime`) drives AOT-compiled HLO
//! through a PJRT CPU client. The real `xla` crate links libxla, which
//! is not available in offline/CI environments — this stub provides the
//! exact API surface the repo compiles against, with every runtime
//! entry point failing fast at [`PjRtClient::cpu`].
//!
//! Because `Engine::load` creates the client before anything else, no
//! other stub method is ever reached: tests and benches that need PJRT
//! already skip themselves when `artifacts/manifest.json` is absent.
//! Swapping in the real bindings is a one-line change in the workspace
//! manifest; no `gogh` source changes are needed.

use std::fmt;

/// Error type matching the shape `gogh` formats with `{e}`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_unreachable() -> Error {
    Error(
        "PJRT stub: executable paths are unreachable without a client \
         (vendor/xla is a build-only stub)"
            .to_string(),
    )
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// PJRT client handle. [`PjRtClient::cpu`] always errors in the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error(
            "PJRT runtime not linked: this build uses the in-tree stub crate \
             (vendor/xla). Point the workspace at a real PJRT-backed `xla` \
             crate to execute AOT artifacts"
                .to_string(),
        ))
    }

    pub fn platform_name(&self) -> &'static str {
        "stub"
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_unreachable())
    }
}

/// Parsed HLO module (text form).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(stub_unreachable())
    }
}

/// An XLA computation built from a parsed HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with host arguments (`Literal` or `&Literal`), returning
    /// per-device, per-output buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_unreachable())
    }
}

/// A device buffer produced by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_unreachable())
    }
}

/// A host tensor value.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_values: &[T]) -> Self {
        Self { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Self { _private: () })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(stub_unreachable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(stub_unreachable())
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(stub_unreachable())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        Err(stub_unreachable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_fast_with_actionable_message() {
        let err = PjRtClient::cpu().err().expect("stub must not succeed");
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn literal_construction_is_usable_pre_execute() {
        // Estimator::batch_literal builds literals before executing;
        // construction and reshape must therefore succeed in the stub.
        let l = Literal::vec1(&[0.0f32; 8]).reshape(&[2, 4]);
        assert!(l.is_ok());
    }
}
